"""The kernel execution backend: per-shard batch coalescing with
calibrated pricing.

A :class:`KernelBackend` replaces the serving engine's per-job analytic
compute pricing.  Jobs submit their work deltas (distance comps, PQ
lookups) as they yield; the backend holds them in an *open batch* for up
to one batch window, then flushes the whole batch as a single fused
dispatch priced from a measured :class:`~repro.exec.table.CalibrationTable`
at the batch's aggregate operating point.  Larger batches hit the
calibration curve where per-op cost is lower — the MXU-utilization /
latency trade the batch window knob controls.

Timing-only by construction: results still come from the unchanged plan
generators, so result IDs and recall are bit-identical to the analytic
backend (the parity contract, enforced by ``tests/test_exec.py``).  The
real padded batched execution lives in :mod:`repro.exec.batched` and is
what the calibration harness times.

Determinism: the flush event is scheduled whenever the first job joins a
window (tracer or not), continuations fire in submission order, and all
pricing is plain float arithmetic off the committed table — a traced run
stays bit-exact against an untraced one.
"""
from __future__ import annotations

from repro.exec.batched import QUERY_TILE, pad_amount
from repro.exec.table import CalibrationTable

__all__ = ["KernelBackend"]


class _Detached:
    """Sentinel parent forcing a root span (batch spans cover many jobs,
    so nesting them under any one job's span would break the tree
    invariant "child interval inside parent interval")."""

    sid = None


_DETACHED = _Detached()


class _Pending:
    """One job's work since its last yield, waiting in the open batch."""

    __slots__ = ("st", "t_enq", "d_dist", "d_pq", "dim", "pq_m", "cont",
                 "interval")

    def __init__(self, st, t_enq, d_dist, d_pq, cont, interval=None):
        self.st = st
        self.t_enq = t_enq
        self.d_dist = d_dist
        self.d_pq = d_pq
        self.dim = st.dim
        self.pq_m = st.pq_m
        self.cont = cont
        self.interval = interval     # mutable [enq_t, flush_t] on st.coalesce


class KernelBackend:
    """Batch coalescer + calibrated pricing for one engine (one shard
    instance).  Attach via :meth:`attach`; the engine then routes every
    compute charge through :meth:`submit` instead of the analytic model.
    """

    def __init__(self, table: CalibrationTable, window_s: float = 0.0, *,
                 shard_id: int = 0, instance: int = 0):
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        self.table = table
        self.window_s = float(window_s)
        self.shard_id = shard_id
        self.instance = instance
        self.kernel = None
        self._open: list[_Pending] = []
        self._flush_ev = None
        # aggregate stats, tracer or not (read by benches and tests)
        self.batches = 0
        self.jobs_batched = 0
        self.occupancy_sum = 0.0
        self.busy_s = 0.0

    def attach(self, engine) -> "KernelBackend":
        self.kernel = engine.kernel
        return self

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0

    # -- engine-facing -------------------------------------------------

    def submit(self, st, t: float, d_dist: int, d_pq: int, cont) -> None:
        """Price ``st``'s work since its last yield; call ``cont(t_done)``.

        Zero-work submissions (graph fetch hops do no shard arithmetic)
        continue immediately — holding them a window would buy nothing.
        Otherwise the job joins the shard's open batch; the first joiner
        arms the flush timer at ``t + window``.  ``window == 0``
        degenerates to per-job calibrated pricing (batch of one).
        """
        if d_dist == 0 and d_pq == 0:
            cont(t)
            return
        if self.window_s <= 0.0:
            self._fire([_Pending(st, t, d_dist, d_pq, cont)], t)
            return
        interval = [t, None]
        st.coalesce.append(interval)
        self._open.append(_Pending(st, t, d_dist, d_pq, cont, interval))
        if self._flush_ev is None:
            self._flush_ev = self.kernel.at(t + self.window_s, self._flush)

    def _flush(self) -> None:
        self._flush_ev = None
        batch, self._open = self._open, []
        t = self.kernel.now
        live = []
        for p in batch:
            if not p.st.alive:       # aborted while waiting; drop silently
                continue
            p.interval[1] = t
            live.append(p)
        if live:
            self._fire(live, t)

    # -- pricing -------------------------------------------------------

    def _fire(self, entries: list[_Pending], t: float) -> None:
        """Price the batch as one fused dispatch and fire continuations.

        Each job's work is charged at the *batch's* aggregate operating
        point on the calibration curve, and the dispatch runs for the
        sum — every member completes at the same ``t + dt``.
        """
        total_dd = sum(p.d_dist for p in entries)
        total_lk = sum(p.d_pq * max(p.pq_m, 1) for p in entries)
        dt = 0.0
        for p in entries:
            dt += self.table.plan_seconds(
                p.d_dist, p.d_pq, p.dim, p.pq_m,
                dist_batch=total_dd, adc_batch=total_lk)
        done_t = t + dt
        b = len(entries)
        self.batches += 1
        self.jobs_batched += b
        occ = b / (b + pad_amount(b, QUERY_TILE))
        self.occupancy_sum += occ
        self.busy_s += dt
        tr = self.kernel.tracer
        if tr.enabled:
            tr.record("batch_compute", t, done_t, parent=_DETACHED,
                      shard=self.shard_id, instance=self.instance,
                      jobs=b, occupancy=round(occ, 4),
                      dist_comps=total_dd, pq_lookups=total_lk)
            m = tr.metrics
            m.counter("exec.batches").inc()
            m.counter("exec.batched_jobs").inc(b)
            m.gauge(f"exec.shard{self.shard_id}.batch_occupancy").set(occ)
            m.gauge(f"exec.shard{self.shard_id}.pad_waste").set(1.0 - occ)
            m.histogram("exec.batch_jobs", lo=1.0, hi=1e3).observe(b)
            m.histogram("exec.batch_occupancy",
                        lo=1e-2, hi=1.0).observe(occ)
            m.histogram("exec.batch_compute_s").observe(dt)
        for p in entries:
            p.cont(done_t)
