"""End-to-end training driver: ~100M-param dense LM on the synthetic
bigram language, with checkpointing/resume and the fault-tolerant runner.

    PYTHONPATH=src python examples/train_lm.py [--steps 150] [--quick]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import LM
from repro.train import optimizer as opt
from repro.train.runner import RunnerConfig, run
from repro.train.train_step import make_train_step

# ~100M params: 51M embedding+head (vocab 50k x 512) + ~50M blocks
CFG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=16, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=2048, vocab=50_000, mlp="swiglu",
    dtype="float32", remat=False)

CFG_QUICK = dataclasses.replace(
    CFG_100M, name="repro-8m", n_layers=4, d_model=128, d_ff=512,
    vocab=4096, n_heads=4, n_kv_heads=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = CFG_QUICK if args.quick else CFG_100M
    lm = LM(cfg)
    print(f"model {cfg.name}: {cfg.n_params()/1e6:.1f}M params")
    params = lm.init(jax.random.PRNGKey(0))
    ocfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=20,
                               total_steps=args.steps)
    opt_state = opt.init_state(params)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=0))
    step_fn = jax.jit(make_train_step(lm, ocfg), donate_argnums=(0, 1))
    rcfg = RunnerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                        ckpt_every=50, log_every=10)
    nb = lambda s: jax.tree.map(jnp.asarray, pipe.batch(s))
    params, opt_state, report = run(rcfg, step_fn, params, opt_state, nb)
    print(f"ran {report.steps_run} steps; "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}; "
          f"stragglers {report.n_stragglers}")
    first, last = np.mean(report.losses[:10]), np.mean(report.losses[-10:])
    assert last < first, "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
