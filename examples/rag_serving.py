"""End-to-end RAG serving: LM embeddings -> cloud vector index ->
retrieve -> prefill -> decode.

The integration deliverable (DESIGN.md §4): the paper's cloud-native
vector index serves as the retrieval layer for any assigned architecture;
here a reduced gemma-family model embeds documents and generates
continuations conditioned on retrieved context, with the retrieval I/O
priced by the TOS simulator.

    PYTHONPATH=src python examples/rag_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS, smoke
from repro.core.cluster_index import ClusterIndex
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.embedder import embed_tokens
from repro.models.model import LM
from repro.serve.decode import generate
from repro.serving.engine import run_workload
from repro.storage.spec import TOS


def main():
    cfg = smoke(ARCHS["gemma-2b"])
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=64, seed=0))

    # ---- corpus: 256 synthetic documents, embedded by the LM ------------
    print("embedding 256 documents with the LM backbone...")
    docs = np.concatenate(
        [pipe.batch(s)["tokens"] for s in range(4)])          # (256, 32)
    embed = jax.jit(
        lambda p, b: lm._backbone(p, b).astype(jnp.float32).mean(1))
    doc_vecs = []
    for s in range(0, len(docs), 64):
        v = np.asarray(embed(params, {"tokens": jnp.asarray(
            docs[s:s + 64])}))
        doc_vecs.append(v / np.linalg.norm(v, axis=1, keepdims=True))
    doc_vecs = np.concatenate(doc_vecs).astype(np.float32)

    # ---- index on simulated cloud storage --------------------------------
    print("building cloud vector index over document embeddings...")
    idx = ClusterIndex.build(doc_vecs, ClusterIndexParams(
        centroid_frac=0.2, num_replica=4))

    # ---- serve: retrieve + generate --------------------------------------
    query_batch = pipe.batch(100)["tokens"][:4]               # 4 queries
    qv = np.asarray(embed(params, {"tokens": jnp.asarray(query_batch)}))
    qv = (qv / np.linalg.norm(qv, axis=1, keepdims=True)).astype(np.float32)

    rep = run_workload(idx, qv, SearchParams(k=4, nprobe=8), TOS,
                       concurrency=4)
    print(f"retrieval on {TOS.name}: p50 "
          f"{rep.latency_percentile(50)*1e3:.1f} ms, "
          f"{rep.mean_bytes_read/1e3:.1f} KB/query")

    for i, rec in enumerate(rep.records):
        top = rec.ids[rec.ids >= 0][:2]
        # prompt = retrieved docs + query tokens
        ctx = np.concatenate([docs[d] for d in top] + [query_batch[i]])
        prompt = jnp.asarray(ctx[None, -64:])
        out = generate(lm, params, {"tokens": prompt}, n_tokens=8)
        print(f"query {i}: retrieved docs {list(top)}, "
              f"generated tokens {out[0].tolist()}")

    print("done.")


if __name__ == "__main__":
    main()
