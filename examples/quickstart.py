"""Quickstart: build both index classes, serve a workload on simulated
cloud storage, and compare against the paper's cost model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.cluster_index import ClusterIndex
from repro.core.cost_model import (ClusterWorkloadPoint, GraphWorkloadPoint,
                                   cluster_query_cost, graph_query_cost)
from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              SearchParams)
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.serving.engine import run_workload
from repro.storage.spec import TOS


def main():
    print("== dataset: deep-analog (96-D f32), 4000 vectors ==")
    spec = scaled(DEEP_ANALOG, 4000, 32)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)

    print("building SPANN-style cluster index...")
    ci = ClusterIndex.build(data, ClusterIndexParams())
    print(f"  {ci.meta.n_lists} posting lists, "
          f"{ci.meta.index_bytes/1e6:.1f} MB, "
          f"avg list {ci.meta.avg_list_bytes/1e3:.1f} KB")

    print("building DiskANN-style graph index...")
    gi = GraphIndex.build(data, GraphIndexParams(R=32, L_build=64,
                                                 pq_dims=48))
    print(f"  {gi.meta.n_data} nodes x {gi.meta.node_nbytes} B blocks, "
          f"{gi.meta.index_bytes/1e6:.1f} MB")

    print(f"\nserving 32 queries on {TOS.describe()}")
    for name, idx, sp in [
        ("SPANN  nprobe=32      ", ci, SearchParams(k=10, nprobe=32)),
        ("DiskANN L=80 W=8      ", gi,
         SearchParams(k=10, search_len=80, beamwidth=8)),
    ]:
        rep = run_workload(idx, queries, sp, TOS, concurrency=4)
        recall = rep.recall_against(gt)
        print(f"  {name} recall={recall:.3f} qps={rep.qps:7.1f} "
              f"p50={rep.latency_percentile(50)*1e3:6.1f} ms "
              f"roundtrips={rep.mean_roundtrips:5.1f} "
              f"MB/q={rep.mean_bytes_read/1e6:6.2f}")

    print("\ncost-model predictions (paper Eq. 1 / Eq. 2):")
    cpred = cluster_query_cost(TOS, ClusterWorkloadPoint(
        n_lists=ci.meta.n_lists, avg_list_bytes=ci.meta.avg_list_bytes,
        avg_list_len=float(ci.meta.list_lengths.mean()), dim=spec.dim,
        nprobe=32))
    gpred = graph_query_cost(TOS, GraphWorkloadPoint(
        roundtrips=10, requests_per_round=8,
        node_nbytes=gi.meta.node_nbytes, R=32, pq_m=gi.meta.pq.m,
        dim=spec.dim))
    print(f"  cluster: total={cpred['total']*1e3:.1f} ms "
          f"(fetch {cpred['c_fetch']*1e3:.1f} / dist "
          f"{cpred['c_dist']*1e3:.2f})")
    print(f"  graph:   total={gpred['total']*1e3:.1f} ms "
          f"(ttfb {gpred['ttfb_total']*1e3:.1f})")


if __name__ == "__main__":
    main()
