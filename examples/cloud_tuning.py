"""Actionable index selection & tuning from the paper's cost models
(answers to RQ1/RQ2/RQ3 as a decision procedure).

Given a workload (dataset dims/dtype, target recall, concurrency) and an
environment (storage spec, cache size), predict both index classes' QPS
from Eq. (1)/(2) + the environment ceilings, and print the recommendation
with the paper's tuning rules applied.

    PYTHONPATH=src python examples/cloud_tuning.py
"""
import dataclasses

from repro.core.cost_model import (ClusterWorkloadPoint, GraphWorkloadPoint,
                                   cluster_query_cost, graph_query_cost,
                                   predicted_qps)
from repro.storage.spec import PRESETS, SSD, TOS


@dataclasses.dataclass
class Workload:
    name: str
    n: int                  # dataset size
    dim: int
    dtype_bytes: int
    recall: float           # target
    concurrency: int


# empirical parameter curves from the paper (§5.2): knobs needed per
# recall level, scaled by dataset characteristics
def _nprobe_for(recall, dim):
    base = {0.7: 16, 0.9: 64, 0.95: 128, 0.99: 512, 0.995: 2048}[recall]
    return max(8, int(base * (dim / 960) ** 0.5))


def _rt_for(recall, n):
    import math
    base = {0.7: 7, 0.9: 15, 0.95: 22, 0.99: 34, 0.995: 43}[recall]
    return max(4, int(base * math.log2(max(n, 2)) / math.log2(1e6)))


def recommend(w: Workload, env=TOS, cache_frac: float = 0.0) -> dict:
    n_lists = int(0.16 * w.n)
    avg_len = w.n * 1.8 / n_lists                     # closure replication
    list_bytes = avg_len * (w.dim * w.dtype_bytes + 8)
    nprobe = _nprobe_for(w.recall, w.dim)
    c = cluster_query_cost(env, ClusterWorkloadPoint(
        n_lists=n_lists, avg_list_bytes=list_bytes, avg_list_len=avg_len,
        dim=w.dim, nprobe=nprobe), concurrency=w.concurrency)
    hit = cache_frac * 0.8                            # hot-set locality
    qps_c = predicted_qps(env, c["total"], c["bytes"] * (1 - hit),
                          c["requests"] * (1 - hit), w.concurrency)

    rt = _rt_for(w.recall, w.n)
    node_b = 4096 * max(1, -(-(w.dim * w.dtype_bytes + 64 * 4) // 4096))
    g = graph_query_cost(env, GraphWorkloadPoint(
        roundtrips=rt, requests_per_round=16, node_nbytes=node_b,
        R=64, pq_m=max(48, w.dim // 8), dim=w.dim),
        concurrency=w.concurrency)
    qps_g = predicted_qps(env, g["total"], g["bytes"],
                          g["requests"], w.concurrency)

    pick = "graph (DiskANN-class)" if qps_g > qps_c else \
        "cluster (SPANN-class)"
    tips = []
    if pick.startswith("cluster"):
        if w.concurrency >= 16 and w.recall >= 0.95:
            tips.append("I/O congested: raise centroid%% to ~32 "
                        "(fine-grained lists; paper Fig 14)")
        if cache_frac > 0.2:
            tips.append("mid-size cache: consider replica=2-4 for higher "
                        "hit rate (paper Fig 24)")
        else:
            tips.append("keep replica=8 (quality; paper Fig 16)")
    else:
        tips.append("build dense graph R=256 (paper Fig 17)")
        if w.concurrency <= 4 and w.recall >= 0.99:
            tips.append("raise beamwidth to 32-64 (ad-hoc high recall; "
                        "paper Fig 19)")
        else:
            tips.append("keep beamwidth<=16 (IOPS ceiling; paper Fig 19f)")
    return dict(pick=pick, qps_cluster=qps_c, qps_graph=qps_g, tips=tips)


def main():
    wide = [
        Workload("adhoc-recs", 10_000_000, 96, 1, 0.9, 1),
        Workload("agentic-rag", 1_000_000, 960, 4, 0.995, 64),
        Workload("ecommerce", 100_000_000, 128, 1, 0.95, 16),
        Workload("fraud-high-recall", 1_000_000, 960, 4, 0.99, 4),
    ]
    for env_name in ["volcano-tos", "local-ssd"]:
        env = PRESETS[env_name]
        print(f"\n=== environment: {env.describe()} ===")
        for w in wide:
            r = recommend(w, env)
            print(f"  {w.name:20s} recall>={w.recall} conc={w.concurrency:3d}"
                  f" -> {r['pick']:24s} "
                  f"(qps c={r['qps_cluster']:8.1f} g={r['qps_graph']:8.1f})")
            for t in r["tips"]:
                print(f"      - {t}")


if __name__ == "__main__":
    main()
