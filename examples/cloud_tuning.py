"""Actionable index selection & tuning — now a thin client of the
``repro.tuning`` subsystem (RQ1/RQ2/RQ3 as a decision system).

For each (workload, environment) pair the auto-tuner enumerates the joint
{index class} × {build} × {search} × {cache policy} space, prunes ≥90% of
it with the paper's analytic cost models, and (optionally) refines the
survivors on the real engine + storage simulator before recommending.

    python examples/cloud_tuning.py              # fast analytic screen
    python examples/cloud_tuning.py --simulate   # + simulation refinement

For one-off tuning with JSON output use the CLI directly:

    python -m repro.tuning --recall 0.95 --concurrency 64 --dim 960 \
        --storage tos
"""
import argparse

from repro.tuning import (EnvSpec, EvalBudget, WorkloadSpec, autotune,
                          resolve_storage)

WORKLOADS = [
    ("adhoc-recs", WorkloadSpec(n=10_000_000, dim=96, dtype="float32",
                                target_recall=0.9, concurrency=1)),
    ("agentic-rag", WorkloadSpec(n=1_000_000, dim=960, dtype="float32",
                                 target_recall=0.995, concurrency=64,
                                 query_dist="zipf")),
    ("ecommerce", WorkloadSpec(n=100_000_000, dim=128, dtype="int8",
                               target_recall=0.95, concurrency=16)),
    ("fraud-high-recall", WorkloadSpec(n=1_000_000, dim=960,
                                       dtype="float32", target_recall=0.99,
                                       concurrency=4)),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--simulate", action="store_true",
                    help="refine screen survivors on the real simulator "
                         "(slower, higher fidelity)")
    ap.add_argument("--cache-gb", type=float, default=0.0)
    args = ap.parse_args()

    budget = EvalBudget(rungs=((400, 16),), max_rung0=6) \
        if args.simulate else "screen"
    for env_name in ["tos", "ssd"]:
        env = EnvSpec(storage=resolve_storage(env_name),
                      cache_bytes=int(args.cache_gb * 2**30))
        print(f"\n=== environment: {env.describe()} ===")
        for name, w in WORKLOADS:
            rec = autotune(w, env, budget=budget)
            print(f"  {name:20s} recall>={w.target_recall} "
                  f"conc={w.concurrency:3d} -> {rec.config.label()}")
            print(f"      predicted: {rec.pred_qps:9.1f} QPS at recall "
                  f"{rec.pred_recall:.3f} (screen kept "
                  f"{rec.screen_kept}/{rec.screen_total})")
            for t in rec.tips:
                print(f"      - {t}")


if __name__ == "__main__":
    main()
