"""repro.obs: tracing is bit-exact, span trees are well-formed, the
Perfetto export is valid JSON, and critical-path attribution accounts
for the measured sojourn.  Plus the CLI plumbing and the one-sort
percentile cache regression test."""
import dataclasses
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              QueryMetrics, SearchParams)
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.fleet.metrics import FleetQueryRecord, FleetReport
from repro.obs import (MetricsRegistry, Tracer, attribute, chrome_trace,
                       extract_paths, flame_summary, run_manifest,
                       trace_diff, write_chrome_trace)
from repro.obs.critical_path import STAGES
from repro.obs.manifest import config_hash
from repro.serving.engine import run_workload
from repro.sim.arrivals import Poisson
from repro.sim.faults import FaultSchedule, ShardFault
from repro.storage.spec import TOS

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4, seed=0))
    gi = GraphIndex.build(data, GraphIndexParams(
        R=24, L_build=48, build_passes=1, pq_dims=24, seed=0))
    return data, queries, ci, gi


HEDGED_CFG = FleetConfig(n_shards=4, replication=2, concurrency=16,
                         shard_concurrency=4, queue_depth=16,
                         hedge=True, hedge_percentile=75.0, seed=5)


@pytest.fixture(scope="module")
def traced_hedged(setup):
    """One traced 4-shard hedged run, shared by the span-shape tests."""
    _, queries, ci, _ = setup
    tracer = Tracer()
    rep = run_fleet(ci, queries, SearchParams(k=10, nprobe=16),
                    HEDGED_CFG, tracer=tracer)
    return rep, tracer


# ----------------------------------------------------- bit-exactness --

def _ids_sha256(report) -> str:
    h = hashlib.sha256()
    for r in sorted(report.records, key=lambda r: r.qid):
        h.update(np.asarray(r.qid).tobytes())
        h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
    return h.hexdigest()


def test_traced_fleet_reproduces_golden(setup):
    """Acceptance: tracing observes, never perturbs — a traced run
    still reproduces the pre-refactor golden reports bit for bit."""
    _, queries, ci, _ = setup
    golden = json.load(open(GOLDEN_PATH))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64, seed=0),
        four_shard=HEDGED_CFG)
    for name, cfg in configs.items():
        rep = run_fleet(ci, queries, p, cfg, tracer=Tracer())
        g = golden[name]
        assert rep.wall_time_s == pytest.approx(g["wall_time_s"],
                                                rel=1e-9, abs=1e-12)
        assert rep.qps == pytest.approx(g["qps"], rel=1e-9)
        assert _ids_sha256(rep) == g["ids_sha256"]


def test_traced_report_bit_identical_to_untraced(setup, traced_hedged):
    _, queries, ci, _ = setup
    plain = run_fleet(ci, queries, SearchParams(k=10, nprobe=16),
                      HEDGED_CFG)
    traced, _ = traced_hedged
    assert plain.to_json() == traced.to_json()


def test_traced_open_loop_with_faults_bit_identical(setup):
    """The heavier codepaths (arrivals, faults, series ticker) are also
    untouched by the tracer's presence."""
    _, queries, ci, _ = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=4, replication=2, concurrency=16,
                      shard_concurrency=4, queue_depth=16, seed=7)
    faults = FaultSchedule((ShardFault(shard=1, t_fail=0.01,
                                       t_recover=0.05),))
    kw = dict(arrivals=Poisson(rate_qps=400.0, n_total=2 * len(queries)),
              slo_s=0.05, faults=faults)
    plain = run_fleet(ci, queries, p, cfg,
                      arrivals=Poisson(rate_qps=400.0,
                                       n_total=2 * len(queries)),
                      slo_s=0.05, faults=faults)
    traced = run_fleet(ci, queries, p, cfg, tracer=Tracer(), **kw)
    assert plain.to_json() == traced.to_json()


# -------------------------------------------------- span well-formedness --

EPS = 1e-9


def _assert_well_formed(tracer):
    spans = tracer.spans
    assert spans, "traced run produced no spans"
    for sp in spans:
        assert sp.t1 is not None, f"unclosed span {sp.name}#{sp.sid}"
        assert sp.t1 >= sp.t0 - EPS
        if sp.parent is None:
            continue
        assert 0 <= sp.parent < sp.sid, "parent must precede child"
        par = spans[sp.parent]
        assert sp.t0 >= par.t0 - EPS, \
            f"{sp.name}#{sp.sid} starts before parent {par.name}"
        assert sp.t1 <= par.t1 + EPS, \
            f"{sp.name}#{sp.sid} ends after parent {par.name}"


def test_span_tree_well_formed_hedged(traced_hedged):
    _, tracer = traced_hedged
    _assert_well_formed(tracer)
    names = {sp.name for sp in tracer.spans}
    assert {"query", "round", "shard_job"} <= names
    # hedge-race losers are parentless by design, and marked wasted
    for sp in tracer.spans:
        if sp.name == "shard_job" and sp.parent is None:
            assert sp.attrs.get("wasted") is True


def test_span_tree_well_formed_graph_multiround(setup):
    """Graph fleets run multiple scatter-gather rounds per query: the
    round spans must still nest correctly under the query root."""
    _, queries, _, gi = setup
    tracer = Tracer()
    run_fleet(gi, queries, SearchParams(k=10, search_len=40, beamwidth=8),
              FleetConfig(n_shards=4, replication=2, concurrency=8,
                          shard_concurrency=4, queue_depth=32, seed=3),
              tracer=tracer)
    _assert_well_formed(tracer)
    by_parent = tracer.children_index()
    multi = [sp for sp in tracer.spans if sp.name == "query"
             and sum(c.name == "round"
                     for c in by_parent.get(sp.sid, [])) > 1]
    assert multi, "expected at least one multi-round graph query"


def test_single_engine_trace(setup):
    """The single-node QueryEngine produces flat query trees with the
    fetch/compute legs directly under the root."""
    _, queries, ci, _ = setup
    tracer = Tracer()
    run_workload(ci, queries, SearchParams(k=10, nprobe=16), _quiet(TOS),
                 concurrency=8, cache_policy="none", tracer=tracer)
    _assert_well_formed(tracer)
    roots = [sp for sp in tracer.spans if sp.name == "query"]
    assert len(roots) == len(queries)
    leg_names = {sp.name for sp in tracer.spans if sp.parent is not None}
    assert "storage_fetch" in leg_names or "cache_fetch" in leg_names


def test_sim_time_monotone_per_lane(traced_hedged):
    """Span ids are issued in begin order, so t0 is non-decreasing in
    sid only within one query tree; globally spans interleave — but a
    child never begins before its local root."""
    _, tracer = traced_hedged
    spans = tracer.spans
    for sp in spans:
        p = sp.parent
        while p is not None:
            root = spans[p]
            p = root.parent
            if p is None:
                assert sp.t0 >= root.t0 - EPS


# ----------------------------------------------------------- export --

def test_chrome_trace_schema(traced_hedged, tmp_path):
    _, tracer = traced_hedged
    path = tmp_path / "trace.json"
    write_chrome_trace(path, tracer)
    doc = json.loads(path.read_text())      # round-trips as valid JSON
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ms"
    begins: dict = {}
    for ev in events:
        assert isinstance(ev.get("ph"), str)
        if ev["ph"] in ("b", "e", "i", "s", "f", "C"):
            assert isinstance(ev["ts"], (int, float))
            assert isinstance(ev["pid"], int)
            assert isinstance(ev["tid"], int)
            assert isinstance(ev["name"], str)
        if ev["ph"] == "b":
            begins[(ev["id"], ev["name"], ev["ts"])] = \
                begins.get((ev["id"], ev["name"], ev["ts"]), 0) + 1
        for v in ev.get("args", {}).values():
            assert v is None or isinstance(v, (bool, int, float, str))
    n_b = sum(1 for ev in events if ev["ph"] == "b")
    n_e = sum(1 for ev in events if ev["ph"] == "e")
    assert n_b == n_e == len(tracer.spans)
    assert sum(1 for ev in events if ev["ph"] == "s") == len(tracer.flows)
    # lane metadata names every process that carries events
    pids = {ev["pid"] for ev in events if ev["ph"] != "M"}
    named = {ev["pid"] for ev in events
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert pids <= named


def test_chrome_trace_counters_present(traced_hedged):
    _, tracer = traced_hedged
    doc = chrome_trace(tracer)
    counters = [ev for ev in doc["traceEvents"] if ev["ph"] == "C"]
    assert any(ev["name"] == "fleet.queue_depth" for ev in counters)


def test_flame_summary_deterministic(traced_hedged):
    _, tracer = traced_hedged
    a = flame_summary(tracer)
    b = flame_summary(tracer)
    assert a == b
    assert "query" in a and "shard_job" in a


# ------------------------------------------------------- attribution --

def test_attribution_accounts_for_sojourn(traced_hedged):
    """Acceptance: the per-stage breakdown sums to the measured mean
    sojourn within 1% (in practice: float-error exact)."""
    rep, tracer = traced_hedged
    att = attribute(tracer)
    measured = float(np.mean([r.sojourn for r in rep.records]))
    assert att.mean_sojourn == pytest.approx(measured, rel=1e-9)
    accounted = sum(att.overall.values())
    assert accounted == pytest.approx(att.mean_sojourn, rel=0.01)
    assert set(att.overall) <= set(STAGES)
    d = att.to_dict()
    assert d["n_queries"] == len(rep.records)
    assert att.render()        # renders without raising


def test_per_query_paths_tile_sojourn(traced_hedged):
    rep, tracer = traced_hedged
    paths = extract_paths(tracer)
    assert len(paths) == len(rep.records)
    for qp in paths:
        assert qp.accounted == pytest.approx(qp.sojourn, rel=1e-6,
                                             abs=1e-12)


def test_trace_diff_zero_and_antisymmetric(traced_hedged, setup):
    rep, tracer = traced_hedged
    a = attribute(tracer).to_dict()
    assert trace_diff(a, a)["mean_sojourn_delta_s"] == 0.0
    assert all(v == 0.0
               for v in trace_diff(a, a)["stages_delta_s"].values())
    _, queries, ci, _ = setup
    tr2 = Tracer()
    run_fleet(ci, queries, SearchParams(k=10, nprobe=16),
              dataclasses.replace(HEDGED_CFG, hedge=False, seed=11),
              tracer=tr2)
    b = attribute(tr2).to_dict()
    ab, ba = trace_diff(a, b), trace_diff(b, a)
    assert ab["mean_sojourn_delta_s"] == -ba["mean_sojourn_delta_s"]
    for k, v in ab["stages_delta_s"].items():
        assert v == -ba["stages_delta_s"][k]


# ----------------------------------------------------------- metrics --

def test_metrics_registry():
    m = MetricsRegistry()
    m.counter("q").inc()
    m.counter("q").inc(2)
    m.gauge("depth").set(7)
    h = m.histogram("lat_s")
    for v in (0.001, 0.002, 0.004, 0.1):
        h.observe(v)
    m.snapshot(0.5)
    m.counter("q").inc()
    m.snapshot(1.0)
    d = m.to_dict()
    assert d["counters"]["q"] == 4
    assert d["gauges"]["depth"] == 7
    hist = d["histograms"]["lat_s"]
    assert hist["count"] == 4
    assert hist["min"] == pytest.approx(0.001)
    assert hist["max"] == pytest.approx(0.1)
    assert 0.001 <= h.quantile(0.5) <= 0.1
    assert len(m.series) == 2
    t0, row0 = m.series[0]
    assert t0 == 0.5 and row0["q"] == 3


def test_histogram_quantile_bounds():
    from repro.obs.metrics import Histogram
    h = Histogram("lat_s")
    h.observe(0.01)
    assert h.quantile(0.0) == pytest.approx(0.01)
    assert h.quantile(1.0) == pytest.approx(0.01)
    assert h.to_dict()["p50"] == pytest.approx(0.01, rel=0.2)


# ----------------------------------------------------------- manifest --

def test_run_manifest_fields():
    meta = run_manifest(seed=3, config=dict(a=1), wall_s=1.23456,
                        argv=["prog", "--x"])
    assert set(meta) >= {"git_sha", "timestamp", "command", "python",
                         "seed", "config_hash", "wall_s"}
    assert meta["seed"] == 3
    assert meta["command"] == "prog --x"
    assert meta["wall_s"] == 1.235
    # hash is stable across key order, sensitive to values
    assert config_hash(dict(b=2, a=1)) == config_hash(dict(a=1, b=2))
    assert config_hash(dict(a=1)) != config_hash(dict(a=2))


# ---------------------------------------------------------------- CLI --

def test_fleet_cli_trace_and_attrib(tmp_path, capsys):
    from repro.fleet.__main__ import main
    trace_path = tmp_path / "t.json"
    rc = main(["--shards", "2", "--n", "600", "--queries", "16",
               "--trace", str(trace_path), "--attrib", "--compact"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert "attrib" in out and "meta" in out
    assert out["attrib"]["accounted_s"] == pytest.approx(
        out["attrib"]["mean_sojourn_s"], rel=0.01)
    doc = json.loads(trace_path.read_text())
    assert doc["traceEvents"]


def test_fleet_cli_untraced_output_unchanged(capsys):
    """--trace/--attrib off: no obs keys leak into the report."""
    from repro.fleet.__main__ import main
    rc = main(["--shards", "2", "--n", "600", "--queries", "16",
               "--compact"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert "attrib" not in out
    assert "meta" in out          # the manifest is always present


# ------------------------------------------- percentile cache (satellite) --

def test_fleet_report_sorts_once_for_summary(monkeypatch):
    """Regression: summary() on a large record list does ONE sort for
    all latency percentiles + the mean, not one per call."""
    import repro.fleet.metrics as fm

    n = 1_000_000
    rng = np.random.default_rng(0)
    lat = rng.exponential(0.01, n)
    ids = np.arange(10, dtype=np.int64)
    dists = np.zeros(10, dtype=np.float32)
    qm = QueryMetrics()
    records = [FleetQueryRecord(
        qid=i, start_t=0.0, end_t=float(lat[i]), ids=ids, dists=dists,
        metrics=qm, rounds=1, n_jobs=1, shards_touched=1)
        for i in range(n)]
    rep = FleetReport(records=records, shard_stats=[], wall_time_s=1.0,
                      n_shards=1, replication=1, concurrency=1,
                      jobs_total=n, hedges_launched=0, hedge_wins=0,
                      sheds_total=0, submissions_total=n)

    calls = {"n": 0}
    real_sort = fm.np.sort

    def counting_sort(*args, **kw):
        calls["n"] += 1
        return real_sort(*args, **kw)

    monkeypatch.setattr(fm.np, "sort", counting_sort)
    assert calls["n"] == 0                     # lazy until first use
    mean = rep.mean_latency
    for p in (50, 99, 99.9):
        rep.latency_percentile(p)
    assert calls["n"] == 1
    # and the cached-path values match numpy computed from scratch
    assert mean == pytest.approx(float(np.mean(lat)))
    assert rep.latency_percentile(99) == float(np.percentile(lat, 99))


def test_percentile_matches_numpy_exactly():
    rng = np.random.default_rng(1)
    for n in (1, 2, 7, 100):
        arr = np.sort(rng.normal(size=n))
        for p in (0.0, 25.0, 50.0, 75.0, 99.0, 99.9, 100.0):
            assert FleetReport._percentile(arr, p) == \
                float(np.percentile(arr, p))
