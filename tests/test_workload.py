"""Workload-generation tests: determinism and shape of the query streams."""
import numpy as np

from repro.serving.workload import (perturbed_zipf, sequential,
                                    zipf_repeated)


def _queries(n=32, dim=8, seed=3):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, dim)).astype(np.float32)


def test_sequential_identity():
    q = _queries()
    out, ids = sequential(q)
    assert out is q
    np.testing.assert_array_equal(ids, np.arange(len(q)))


def test_zipf_repeated_deterministic_per_seed():
    q = _queries()
    out1, ids1 = zipf_repeated(q, n_total=200, seed=7)
    out2, ids2 = zipf_repeated(q, n_total=200, seed=7)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(out1, out2)


def test_zipf_repeated_seed_sensitivity():
    q = _queries()
    _, ids1 = zipf_repeated(q, n_total=200, seed=7)
    _, ids2 = zipf_repeated(q, n_total=200, seed=8)
    assert not np.array_equal(ids1, ids2)


def test_zipf_repeated_shape_and_mapping():
    q = _queries()
    out, ids = zipf_repeated(q, n_total=150, seed=0)
    assert out.shape == (150, q.shape[1])
    assert ids.shape == (150,)
    assert ids.min() >= 0 and ids.max() < len(q)
    # each emitted query is exactly the original it claims to be
    np.testing.assert_array_equal(out, q[ids])


def test_zipf_repeated_is_long_tailed():
    q = _queries(n=64)
    _, ids = zipf_repeated(q, n_total=2000, a=1.2, seed=1)
    _, counts = np.unique(ids, return_counts=True)
    top = np.sort(counts)[::-1]
    # the hottest query dominates a uniform share by a wide margin
    assert top[0] > 3 * (2000 / 64)


def test_perturbed_zipf_deterministic_and_near_duplicate():
    q = _queries()
    out1, ids1 = perturbed_zipf(q, n_total=100, noise=0.01, seed=5)
    out2, ids2 = perturbed_zipf(q, n_total=100, noise=0.01, seed=5)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(out1, out2)
    base = q[ids1]
    err = np.abs(out1 - base).mean()
    assert 0.0 < err < 0.1 * np.abs(base).mean() + 1e-6
