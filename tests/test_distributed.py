"""Distributed (sharded) vector search: correctness on a tiny real mesh.

The production-scale version is exercised by the dry-run (512 fake
devices); here the same shard_map code runs on a 1-device mesh and must
match flat exact search on the probed set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import sharded_kmeans_step, sharded_search_step
from repro.core.flat import exact_topk


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_sharded_search_matches_flat(mesh):
    rng = np.random.default_rng(0)
    L, M, D, B = 64, 8, 16, 4
    cents = rng.normal(size=(L, D)).astype(np.float32)
    vecs = (cents[:, None, :]
            + rng.normal(0, 0.1, size=(L, M, D))).astype(np.float32)
    ids = np.arange(L * M, dtype=np.int32).reshape(L, M)
    queries = (cents[rng.choice(L, B)]
               + rng.normal(0, 0.05, size=(B, D))).astype(np.float32)

    norms = (vecs.astype(np.float32) ** 2).sum(-1)
    fn = jax.jit(sharded_search_step(mesh, nprobe_local=L, k=5))
    with mesh:
        got_ids, got_d = fn(jnp.asarray(cents), jnp.asarray(vecs),
                            jnp.asarray(ids), jnp.asarray(norms),
                            jnp.asarray(queries))
    flat = vecs.reshape(-1, D)
    want_ids, want_d = exact_topk(flat, queries, 5)
    # ids array maps row-major, so direct comparison works
    np.testing.assert_allclose(np.asarray(got_d), want_d, rtol=1e-4,
                               atol=1e-4)
    for b in range(B):
        assert len(np.intersect1d(np.asarray(got_ids)[b],
                                  want_ids[b])) >= 4


def test_sharded_search_respects_nprobe(mesh):
    rng = np.random.default_rng(1)
    L, M, D, B = 32, 4, 8, 2
    cents = rng.normal(size=(L, D)).astype(np.float32) * 10
    vecs = (cents[:, None, :]
            + rng.normal(0, 0.1, size=(L, M, D))).astype(np.float32)
    ids = np.arange(L * M, dtype=np.int32).reshape(L, M)
    q = (cents[:B] + 0.01).astype(np.float32)
    norms = (vecs.astype(np.float32) ** 2).sum(-1)
    fn = jax.jit(sharded_search_step(mesh, nprobe_local=1, k=3))
    with mesh:
        got_ids, _ = fn(jnp.asarray(cents), jnp.asarray(vecs),
                        jnp.asarray(ids), jnp.asarray(norms),
                        jnp.asarray(q))
    # probing only the nearest list still finds its members
    for b in range(B):
        assert set(np.asarray(got_ids)[b].tolist()) <= set(
            ids[b].tolist())


def test_sharded_kmeans_step_improves(mesh):
    rng = np.random.default_rng(2)
    true = rng.normal(size=(8, 8)).astype(np.float32) * 5
    x = (true[rng.integers(0, 8, 512)]
         + rng.normal(0, 0.3, size=(512, 8))).astype(np.float32)
    cents = x[rng.choice(512, 8, replace=False)]
    step = jax.jit(sharded_kmeans_step(mesh))

    def inertia(c):
        d = ((x[:, None, :] - c[None]) ** 2).sum(-1)
        return d.min(1).mean()

    with mesh:
        c1 = np.asarray(step(jnp.asarray(x), jnp.asarray(cents)))
        c2 = np.asarray(step(jnp.asarray(x), jnp.asarray(c1)))
    assert inertia(c2) <= inertia(np.asarray(cents)) + 1e-5
