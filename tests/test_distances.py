import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distances import (np_sq_l2, pairwise_neg_ip, pairwise_sq_l2,
                                  topk_smallest)


@pytest.mark.parametrize("dtype", [np.float32, np.int8])
@pytest.mark.parametrize("q,n,d", [(4, 64, 16), (1, 7, 960), (8, 128, 100)])
def test_pairwise_matches_numpy(dtype, q, n, d):
    rng = np.random.default_rng(0)
    if dtype == np.int8:
        qs = rng.integers(-127, 128, size=(q, d)).astype(np.int8)
        xs = rng.integers(-127, 128, size=(n, d)).astype(np.int8)
    else:
        qs = rng.normal(size=(q, d)).astype(np.float32)
        xs = rng.normal(size=(n, d)).astype(np.float32)
    got = np.asarray(pairwise_sq_l2(jnp.asarray(qs), jnp.asarray(xs)))
    want = np_sq_l2(qs, xs)
    rtol = 1e-5 if dtype == np.float32 else 0.0
    np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-2)


def test_int8_exact_integer_arithmetic():
    # int8 path must be exact (int32 accumulation, no float rounding)
    rng = np.random.default_rng(1)
    qs = rng.integers(-127, 128, size=(3, 200)).astype(np.int8)
    xs = rng.integers(-127, 128, size=(50, 200)).astype(np.int8)
    got = np.asarray(pairwise_sq_l2(jnp.asarray(qs), jnp.asarray(xs)))
    want = ((qs.astype(np.int64)[:, None, :]
             - xs.astype(np.int64)[None, :, :]) ** 2).sum(-1)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_neg_ip():
    rng = np.random.default_rng(2)
    qs = rng.normal(size=(5, 32)).astype(np.float32)
    xs = rng.normal(size=(11, 32)).astype(np.float32)
    got = np.asarray(pairwise_neg_ip(jnp.asarray(qs), jnp.asarray(xs)))
    np.testing.assert_allclose(got, -(qs @ xs.T), rtol=1e-5, atol=1e-5)


def test_topk_smallest():
    rng = np.random.default_rng(3)
    d = rng.normal(size=(6, 40)).astype(np.float32)
    vals, idx = topk_smallest(jnp.asarray(d), 5)
    want = np.sort(d, axis=1)[:, :5]
    np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-6)
    np.testing.assert_array_equal(
        np.take_along_axis(d, np.asarray(idx), axis=1), np.asarray(vals))


def test_self_distance_zero():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(10, 64)).astype(np.float32)
    d = np.asarray(pairwise_sq_l2(jnp.asarray(x), jnp.asarray(x)))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)
    assert (d >= 0).all()
