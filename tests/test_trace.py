"""Trace record/replay must be observationally identical to live serving."""
import dataclasses

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.graph_index import GraphIndex
from repro.core.types import ClusterIndexParams, GraphIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.serving.engine import EngineConfig, run_workload
from repro.serving.trace import record_traces, replay_workload
from repro.storage.spec import TOS


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1500, 16)
    data, queries = make_dataset(spec)
    ci = ClusterIndex.build(data, ClusterIndexParams(seed=0))
    gi = GraphIndex.build(data, GraphIndexParams(
        R=32, L_build=64, pq_dims=48, seed=0), batch=256)
    return queries, ci, gi


@pytest.mark.parametrize("which", ["cluster", "graph"])
def test_replay_equals_live(setup, which):
    queries, ci, gi = setup
    if which == "cluster":
        index, params = ci, SearchParams(k=10, nprobe=16)
    else:
        index, params = gi, SearchParams(k=10, search_len=40, beamwidth=8)
    for concurrency in [1, 8]:
        for cache in [0, 1 << 22]:
            cfg = EngineConfig(storage=_quiet(TOS), concurrency=concurrency,
                               cache_bytes=cache, seed=1)
            live = run_workload(index, queries, params, _quiet(TOS),
                                concurrency=concurrency, cache_bytes=cache,
                                seed=1)
            traces = record_traces(index, queries, params)
            rep = replay_workload(index, traces, cfg)
            assert rep.qps == pytest.approx(live.qps, rel=1e-9)
            assert rep.wall_time_s == pytest.approx(live.wall_time_s,
                                                    rel=1e-9)
            assert rep.hit_rate == pytest.approx(live.hit_rate, abs=1e-12)
            assert rep.mean_bytes_read == pytest.approx(
                live.mean_bytes_read, rel=1e-12)
            for a, b in zip(rep.records, live.records):
                np.testing.assert_array_equal(a.ids, b.ids)
