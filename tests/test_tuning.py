"""Tests for the ``repro.tuning`` auto-configuration subsystem."""
import json

import numpy as np
import pytest

from repro.core.cost_model import (ClusterWorkloadPoint, GraphWorkloadPoint,
                                   cluster_query_cost, graph_query_cost)
from repro.storage.spec import SSD, TOS
from repro.tuning import (Candidate, EnvSpec, EvalBudget, WorkloadSpec,
                          autotune, best_predicted_qps, enumerate_space,
                          pareto_frontier, predict, resolve_storage, screen)


# ------------------------------------------------------------ cost model --

def test_cluster_cost_hit_rate_discounts_monotonically():
    w = ClusterWorkloadPoint(n_lists=100_000, avg_list_bytes=40_000,
                             avg_list_len=12, dim=960, nprobe=64)
    prev = None
    for hr in [0.0, 0.25, 0.5, 0.75, 1.0]:
        c = cluster_query_cost(TOS, w, concurrency=8, hit_rate=hr)
        if prev is not None:
            assert c["total"] <= prev["total"]
            assert c["bytes"] <= prev["bytes"]
            assert c["requests"] <= prev["requests"]
        prev = c
    # full hit rate: no storage traffic left
    assert prev["bytes"] == 0.0 and prev["requests"] == 0.0


def test_graph_cost_hit_rate_removes_ttfb_floor():
    w = GraphWorkloadPoint(roundtrips=20, requests_per_round=16,
                           node_nbytes=4096, R=64, pq_m=112, dim=960)
    cold = graph_query_cost(TOS, w, hit_rate=0.0)
    warm = graph_query_cost(TOS, w, hit_rate=0.5)
    hot = graph_query_cost(TOS, w, hit_rate=1.0)
    assert warm["total"] < cold["total"]
    assert warm["ttfb_total"] == pytest.approx(cold["ttfb_total"] * 0.5)
    assert hot["bytes"] == 0.0
    assert hot["total"] < 20 * TOS.ttfb_p50_s  # floor gone


def test_hit_rate_zero_matches_legacy_behaviour():
    w = ClusterWorkloadPoint(n_lists=10_000, avg_list_bytes=64_000,
                             avg_list_len=40, dim=960, nprobe=32)
    assert cluster_query_cost(TOS, w) == cluster_query_cost(
        TOS, w, hit_rate=0.0)


# ----------------------------------------------------------------- space --

def test_enumerate_space_policies_follow_cache_budget():
    w = WorkloadSpec(n=1_000_000, dim=960)
    no_cache = enumerate_space(w, EnvSpec(storage=TOS, cache_bytes=0))
    cached = enumerate_space(w, EnvSpec(storage=TOS, cache_bytes=2**30))
    assert {c.cache_policy for c in no_cache} == {"none"}
    assert {c.cache_policy for c in cached} == {"none", "slru", "pinned"}
    assert len(cached) == 3 * len(no_cache)


# ---------------------------------------------------------------- screen --

def test_screen_prunes_at_least_90_percent():
    w = WorkloadSpec(n=1_000_000, dim=960, target_recall=0.9,
                     concurrency=16)
    env = EnvSpec(storage=TOS, cache_bytes=4 * 2**30)
    cands = enumerate_space(w, env)
    res = screen(w, env, cands)
    assert res.prune_fraction >= 0.90
    assert len(res.kept) >= 4


def test_screen_monotone_in_recall_target():
    """A higher recall target can never predict a higher best QPS: the
    feasible set only shrinks as the target rises."""
    env = EnvSpec(storage=TOS)
    prev = float("inf")
    for target in [0.7, 0.9, 0.95, 0.99, 0.995]:
        w = WorkloadSpec(n=1_000_000, dim=960, target_recall=target,
                         concurrency=16)
        preds = [predict(w, env, c) for c in enumerate_space(w, env)]
        best = best_predicted_qps(preds)
        assert best <= prev + 1e-9
        prev = best


def test_screen_recall_priors_monotone_in_knobs():
    env = EnvSpec(storage=TOS)
    w = WorkloadSpec(n=1_000_000, dim=960)
    r_prev = 0.0
    for nprobe in [8, 32, 128, 512, 2048]:
        c = Candidate(kind="cluster", nprobe=nprobe)
        r = predict(w, env, c).pred_recall
        assert r >= r_prev
        r_prev = r
    r_prev = 0.0
    for L in [20, 80, 320, 640]:
        c = Candidate(kind="graph", search_len=L)
        r = predict(w, env, c).pred_recall
        assert r >= r_prev
        r_prev = r


# ---------------------------------------------------------------- pareto --

def test_pareto_frontier_correctness_on_synthetic_set():
    pts = [
        (0.70, 100.0),     # frontier
        (0.90, 80.0),      # frontier
        (0.90, 60.0),      # dominated by (0.90, 80)
        (0.85, 70.0),      # dominated by (0.90, 80)
        (0.99, 20.0),      # frontier
        (0.60, 90.0),      # dominated by (0.70, 100)
        (0.99, 20.0),      # duplicate: collapsed
    ]
    front = pareto_frontier(pts, recall_of=lambda p: p[0],
                            qps_of=lambda p: p[1])
    assert front == [(0.70, 100.0), (0.90, 80.0), (0.99, 20.0)]
    # frontier is recall-ascending and qps-descending
    recalls = [p[0] for p in front]
    qpss = [p[1] for p in front]
    assert recalls == sorted(recalls)
    assert qpss == sorted(qpss, reverse=True)


def test_pareto_single_point_and_empty():
    f = pareto_frontier([(0.5, 1.0)], lambda p: p[0], lambda p: p[1])
    assert f == [(0.5, 1.0)]
    assert pareto_frontier([], lambda p: p[0], lambda p: p[1]) == []


# -------------------------------------------------------------- autotune --

def test_autotune_screen_budget_emits_json():
    w = WorkloadSpec(n=1_000_000, dim=960, target_recall=0.9,
                     concurrency=16)
    rec = autotune(w, EnvSpec(storage=TOS), budget="screen")
    blob = json.loads(rec.to_json())
    assert blob["recommendation"]["kind"] in ("cluster", "graph")
    assert blob["screen"]["prune_fraction"] >= 0.90
    assert blob["pareto_frontier"]
    assert rec.prune_fraction >= 0.90


def test_autotune_e2e_graph_for_high_concurrency_high_dim():
    """Paper rule (RQ2): graph wins the very-high-recall, high-concurrency,
    high-dim regime on cloud storage."""
    w = WorkloadSpec(n=1_000_000, dim=960, target_recall=0.995,
                     concurrency=64)
    budget = EvalBudget(rungs=((300, 12),), max_rung0=6)
    rec = autotune(w, EnvSpec(storage=resolve_storage("tos")),
                   budget=budget)
    assert rec.config.kind == "graph"
    assert rec.simulated > 0


def test_autotune_e2e_cluster_for_low_recall_ssd():
    """Paper rule (RQ1/RQ2): cluster wins at low recall on cheap/fast
    storage."""
    w = WorkloadSpec(n=10_000_000, dim=96, target_recall=0.7,
                     concurrency=1)
    budget = EvalBudget(rungs=((800, 20),), max_rung0=6)
    rec = autotune(w, EnvSpec(storage=resolve_storage("ssd")),
                   budget=budget)
    assert rec.config.kind == "cluster"
    assert rec.simulated > 0
    assert rec.feasible
