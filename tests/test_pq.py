import numpy as np
import pytest

from repro.core.distances import np_sq_l2
from repro.core.pq import ProductQuantizer, default_pq_dims, train_pq


@pytest.fixture(scope="module")
def trained():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(16, 64))
    x = (centers[rng.integers(0, 16, 2000)]
         + rng.normal(0, 0.2, size=(2000, 64))).astype(np.float32)
    pq = train_pq(x, m=8, iters=8, seed=0)
    return x, pq


def test_pq_shapes(trained):
    x, pq = trained
    assert pq.m == 8 and pq.dsub == 8
    codes = pq.encode(x[:100])
    assert codes.shape == (100, 8) and codes.dtype == np.uint8


def test_pq_reconstruction_beats_mean(trained):
    x, pq = trained
    codes = pq.encode(x)
    rec = pq.decode(codes)
    err = ((x - rec) ** 2).sum(1).mean()
    base = ((x - x.mean(0)) ** 2).sum(1).mean()
    assert err < 0.35 * base


def test_adc_equals_distance_to_reconstruction(trained):
    """ADC identity: table-lookup distance == exact distance to decode()."""
    x, pq = trained
    codes = pq.encode(x[:200])
    rec = pq.decode(codes)
    q = x[500]
    table = pq.adc_table(q)
    adc = pq.adc_lookup(codes, table)
    exact = np_sq_l2(q, rec)
    np.testing.assert_allclose(adc, exact, rtol=1e-4, atol=1e-3)


def test_adc_preserves_global_ordering(trained):
    """ADC distances must rank-correlate strongly with exact distances
    (this is what makes PQ-guided traversal converge — §2.3.2)."""
    x, pq = trained
    codes = pq.encode(x)
    q = x[123] + np.random.default_rng(1).normal(0, 0.05, 64).astype(np.float32)
    adc = pq.adc_lookup(codes, pq.adc_table(q))
    exact = np_sq_l2(q, x)
    r_adc = np.argsort(np.argsort(adc)).astype(np.float64)
    r_ex = np.argsort(np.argsort(exact)).astype(np.float64)
    spearman = np.corrcoef(r_adc, r_ex)[0, 1]
    assert spearman > 0.9
    # and the coarse top set is recovered: ADC top-100 catches most of the
    # true top-20 (rerank then fixes the fine ordering)
    top100 = set(np.argsort(adc)[:100].tolist())
    top20 = set(np.argsort(exact)[:20].tolist())
    assert len(top100 & top20) >= 14


def test_pq_padding_non_divisible_dim():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 100)).astype(np.float32)  # 100 % 48 != 0
    pq = train_pq(x, m=48, iters=3, seed=0)
    codes = pq.encode(x[:10])
    rec = pq.decode(codes)
    assert rec.shape == (10, 100)


def test_default_pq_dims():
    assert default_pq_dims(960) == 120
    assert default_pq_dims(96) == 48
    assert default_pq_dims(128) == 48
    assert default_pq_dims(32) == 32
