"""repro.exec: batched MXU execution, calibrated pricing, parity.

Three layers under test:

* **batched execution** (``repro.exec.batched``) — pad-to-tile
  correctness against the numpy oracles: result ids bit-identical on any
  input (the kernel's tie-break must match lexsort), distances
  bit-identical on integer-valued inputs (exact float32 sums);
* **coalescer + pricing** (``repro.exec.backend`` / ``table``) — batch
  window semantics on a bare event kernel, calibration-table
  interpolation and validation;
* **the parity contract** — a kernel-backend fleet run returns
  bit-identical per-query result ids and recall vs the analytic backend
  at every batch window, and is deterministic run to run.
"""
import dataclasses
import json
import types

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DatasetSpec, make_dataset
from repro.exec import (CalibEntry, CalibrationTable, KernelBackend,
                        QUERY_TILE, batched_topk, coalesce_scan,
                        load_table, pad_amount, scan_topk_oracle)
from repro.fleet import FleetConfig, run_fleet
from repro.kernels import ops
from repro.sim.kernel import Kernel


# ---------------------------------------------------------------- setup --

def _mk(b, n, d, seed=0, integer=False):
    rng = np.random.default_rng(seed)
    if integer:      # small integers: float32 sums exact -> bit-exactness
        q = rng.integers(-8, 8, (b, d)).astype(np.float32)
        x = rng.integers(-8, 8, (n, d)).astype(np.float32)
    else:
        q = rng.standard_normal((b, d)).astype(np.float32)
        x = rng.standard_normal((n, d)).astype(np.float32)
    return q, x


@pytest.fixture(scope="module")
def fleet_setup():
    spec = DatasetSpec("exec-test", 32, "float32", 800, 32,
                       n_clusters=16, intrinsic_dim=16, seed=7)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    index = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=2,
                                                        seed=7))
    return index, queries, gt


# ------------------------------------------------------ pad-to-tile MXU --

@pytest.mark.parametrize("b", [1, 2, 5, 7, 8, 9])
def test_batched_topk_ragged_batch_ids_match_oracle(b):
    q, x = _mk(b, 200, 32, seed=b)
    vk, ik = batched_topk(q, x, 10)
    vo, io = scan_topk_oracle(q, x, 10)
    assert vk.shape == (b, 10) and ik.shape == (b, 10)
    np.testing.assert_array_equal(ik, io)
    np.testing.assert_allclose(vk, vo, rtol=1e-5, atol=1e-5)


def test_batched_topk_k_exceeds_candidates():
    q, x = _mk(3, 5, 16, seed=1)
    vk, ik = batched_topk(q, x, 8)
    vo, io = scan_topk_oracle(q, x, 8)
    assert ik.shape == (3, 8)
    # 5 real results, then -1 / +inf fill — identical to the oracle
    np.testing.assert_array_equal(ik, io)
    assert (ik[:, 5:] == -1).all() and np.isinf(vk[:, 5:]).all()
    np.testing.assert_allclose(vk[:, :5], vo[:, :5], rtol=1e-5, atol=1e-5)


def test_batched_topk_duplicate_distances_bit_exact():
    # duplicated candidate rows => exactly tied distances; integer-valued
    # vectors make the sums exact, so ids AND values must be bit-identical
    # (ties broken by candidate id, both sides canonicalized by lexsort)
    q, x = _mk(6, 80, 32, seed=2, integer=True)
    x = np.concatenate([x, x[:40]])          # 40 exact duplicates
    vk, ik = batched_topk(q, x, 10)
    vo, io = scan_topk_oracle(q, x, 10)
    np.testing.assert_array_equal(ik, io)
    np.testing.assert_array_equal(vk, vo)


def test_batched_topk_rows_independent_of_batchmates():
    # each query's result must not depend on what it was batched with
    q, x = _mk(5, 96, 16, seed=3, integer=True)
    vb, ib = batched_topk(q, x, 6)
    for i in range(len(q)):
        v1, i1 = batched_topk(q[i:i + 1], x, 6)
        np.testing.assert_array_equal(i1[0], ib[i])
        np.testing.assert_array_equal(v1[0], vb[i])


def test_batched_topk_empty_edges():
    q, x = _mk(2, 50, 16, seed=4)
    v, i = batched_topk(np.empty((0, 16), np.float32), x, 5)
    assert v.shape == (0, 5) and i.shape == (0, 5)
    v, i = batched_topk(q, x, 0)
    assert v.shape == (2, 0) and i.shape == (2, 0)
    v, i = batched_topk(q, np.empty((0, 16), np.float32), 5)
    assert (i == -1).all() and np.isinf(v).all()


def test_coalesce_scan_maps_global_ids():
    q, x = _mk(4, 60, 16, seed=5)
    gids = np.arange(1000, 1060, dtype=np.int64)
    out = coalesce_scan(list(q), x, gids, 7)    # one query per owner job
    assert len(out) == 4
    _, io = scan_topk_oracle(q, x, 7)
    for j, (dists, ids) in enumerate(out):
        np.testing.assert_array_equal(ids, gids[io[j]])


def test_pad_amount():
    assert pad_amount(0, 8) == 0
    assert pad_amount(1, 8) == 7
    assert pad_amount(8, 8) == 0
    assert pad_amount(9, 8) == 7
    assert pad_amount(120, 128) == 8


def test_default_interpret_cached_and_overridable():
    auto = ops.default_interpret()
    assert ops.default_interpret() is auto       # cached, not re-detected
    try:
        ops.set_default_interpret(True)
        assert ops.default_interpret() is True
        ops.set_default_interpret(False)
        assert ops.default_interpret() is False
    finally:
        ops.set_default_interpret(None)          # re-arm auto-detect
    assert ops.default_interpret() == auto


# ----------------------------------------------------- calibration table --

def _toy_table():
    return CalibrationTable([
        CalibEntry("dist", 32, 0, 100, "float32", 1e-6),
        CalibEntry("dist", 32, 0, 10000, "float32", 1e-8),
        CalibEntry("dist", 128, 0, 100, "float32", 4e-6),
        CalibEntry("adc", 0, 8, 1000, "uint8", 2e-8),
    ], meta={"backend": "test"})


def test_table_roundtrip(tmp_path):
    t = _toy_table()
    p = tmp_path / "cal.json"
    t.save(str(p))
    t2 = CalibrationTable.load(str(p))
    assert [e.to_dict() for e in t2.entries] == \
        [e.to_dict() for e in t.entries]
    assert t2.meta["backend"] == "test"
    assert t2.dist_unit_s(32, 100) == t.dist_unit_s(32, 100)


def test_table_log_interpolation_and_clamp():
    t = _toy_table()
    assert t.dist_unit_s(32, 100) == pytest.approx(1e-6)
    assert t.dist_unit_s(32, 10000) == pytest.approx(1e-8)
    # unit_s interpolates linearly in log(batch): the geometric midpoint
    # of the batch axis lands halfway between the endpoint unit costs
    mid = t.dist_unit_s(32, 1000)
    assert mid == pytest.approx((1e-6 + 1e-8) / 2)
    # outside the measured range: clamped, never extrapolated
    assert t.dist_unit_s(32, 1) == pytest.approx(1e-6)
    assert t.dist_unit_s(32, 1e9) == pytest.approx(1e-8)


def test_table_nearest_bucket():
    t = _toy_table()
    # dim 64 sits between 32 and 128 buckets; log-distance picks one
    assert t.dist_unit_s(64, 100) in (pytest.approx(1e-6),
                                      pytest.approx(4e-6))
    assert t.adc_unit_s(16, 1000) == pytest.approx(2e-8)   # nearest pq_m


def test_table_requires_dist_entries():
    with pytest.raises(ValueError):
        CalibrationTable([CalibEntry("adc", 0, 8, 100, "uint8", 1e-8)])


def test_plan_seconds_batching_amortizes():
    t = _toy_table()
    solo = t.plan_seconds(500, 0, 32, 0)
    # the same work charged at a 100x-bigger batch operating point
    batched = t.plan_seconds(500, 0, 32, 0, dist_batch=50000)
    assert 0 < batched < solo


def test_committed_table_loads_and_prices():
    t = load_table()
    assert t.meta.get("backend")
    assert len(t.entries) > 8
    s = t.plan_seconds(4096, 2048, 64, 8)
    assert 0 < s < 1.0
    # measured amortization: bulk unit cost strictly below batch-of-one
    assert t.dist_unit_s(32, 1e5) < t.dist_unit_s(32, 1)


# ----------------------------------------------------------- coalescer --

def _stub_engine():
    k = Kernel(seed=0)
    return types.SimpleNamespace(kernel=k), k


def _job(dim=32, pq_m=0):
    return types.SimpleNamespace(alive=True, coalesce=[], dim=dim,
                                 pq_m=pq_m)


def test_backend_zero_work_bypasses_window():
    eng, k = _stub_engine()
    be = KernelBackend(load_table(), window_s=1e-3).attach(eng)
    done = []
    be.submit(_job(), 5.0, 0, 0, done.append)
    assert done == [5.0]                     # immediate, no flush event
    assert be.batches == 0 and len(k.queue) == 0


def test_backend_window_zero_is_batch_of_one():
    eng, k = _stub_engine()
    t = load_table()
    be = KernelBackend(t, window_s=0.0).attach(eng)
    done = []
    be.submit(_job(), 1.0, 500, 0, done.append)
    assert be.batches == 1 and be.jobs_batched == 1
    assert done == [1.0 + t.plan_seconds(500, 0, 32, 0)]
    assert be.mean_occupancy == pytest.approx(1 / QUERY_TILE)


def test_backend_coalesces_within_window():
    eng, k = _stub_engine()
    t = load_table()
    be = KernelBackend(t, window_s=1e-4).attach(eng)
    done = []
    j1, j2 = _job(), _job()
    be.submit(j1, 0.0, 400, 0, lambda td: done.append(("a", td)))
    be.submit(j2, 0.0, 600, 0, lambda td: done.append(("b", td)))
    assert len(k.queue) == 1                 # one armed flush, not two
    k.run()
    assert be.batches == 1 and be.jobs_batched == 2
    # both continuations fire at the same fused completion time, in
    # submission order, and the flush happened at t + window
    assert [x[0] for x in done] == ["a", "b"]
    assert done[0][1] == done[1][1]
    expect = 1e-4 + sum(
        t.plan_seconds(d, 0, 32, 0, dist_batch=1000) for d in (400, 600))
    assert done[0][1] == pytest.approx(expect)
    # per-job coalesce intervals recorded for span tiling
    assert j1.coalesce == [[0.0, 1e-4]] and j2.coalesce == [[0.0, 1e-4]]


def test_backend_batching_is_cheaper():
    t = load_table()
    eng, k = _stub_engine()
    be = KernelBackend(t, window_s=1e-4).attach(eng)
    for _ in range(8):
        be.submit(_job(), 0.0, 500, 0, lambda td: None)
    k.run()
    batched_busy = be.busy_s
    assert be.mean_occupancy == 1.0          # full query tile
    solo = 8 * t.plan_seconds(500, 0, 32, 0)
    assert batched_busy < solo


def test_backend_dead_job_dropped_at_flush():
    eng, k = _stub_engine()
    be = KernelBackend(load_table(), window_s=1e-4).attach(eng)
    done = []
    j1, j2 = _job(), _job()
    be.submit(j1, 0.0, 500, 0, lambda td: done.append("a"))
    be.submit(j2, 0.0, 500, 0, lambda td: done.append("b"))
    j1.alive = False                         # aborted while waiting
    k.run()
    assert done == ["b"]
    assert be.batches == 1 and be.jobs_batched == 1


def test_backend_rejects_negative_window():
    with pytest.raises(ValueError):
        KernelBackend(load_table(), window_s=-1e-6)


# ------------------------------------------------------ parity contract --

def _run(index, queries, **cfg_kw):
    base = dict(n_shards=2, replication=1, concurrency=16,
                shard_concurrency=4, queue_depth=32, seed=3)
    base.update(cfg_kw)
    return run_fleet(index, queries, SearchParams(k=10, nprobe=8),
                     FleetConfig(**base))


@pytest.mark.parametrize("window_us", [0.0, 200.0])
def test_fleet_kernel_backend_parity(fleet_setup, window_us):
    index, queries, gt = fleet_setup
    ra = _run(index, queries)
    rk = _run(index, queries, backend="kernel",
              batch_window_s=window_us * 1e-6)
    by_qid = {r.qid: r for r in ra.records}
    assert len(rk.records) == len(ra.records)
    for r in rk.records:
        np.testing.assert_array_equal(r.ids, by_qid[r.qid].ids)
        np.testing.assert_array_equal(r.dists, by_qid[r.qid].dists)
    assert rk.recall_against(gt) == ra.recall_against(gt)


def test_fleet_kernel_backend_deterministic(fleet_setup):
    index, queries, _ = fleet_setup
    r1 = _run(index, queries, backend="kernel", batch_window_s=2e-4)
    r2 = _run(index, queries, backend="kernel", batch_window_s=2e-4)
    assert r1.to_json() == r2.to_json()


def test_fleet_window_grows_batches(fleet_setup):
    index, queries, _ = fleet_setup
    from repro.fleet.router import FleetRouter

    def stats(window_s):
        cfg = FleetConfig(n_shards=2, replication=1, concurrency=16,
                          shard_concurrency=4, queue_depth=32, seed=3,
                          backend="kernel", batch_window_s=window_s)
        router = FleetRouter(index, cfg)
        rep = router.run(queries, SearchParams(k=10, nprobe=8))
        be_stats = [srv.engine.backend for g in router.groups
                    for srv in g.all_servers()]
        jobs = sum(b.jobs_batched for b in be_stats)
        batches = sum(b.batches for b in be_stats)
        return rep, jobs / batches

    rep0, mean0 = stats(0.0)
    rep1, mean1 = stats(2e-3)
    assert mean0 == 1.0
    assert mean1 > 1.0                       # window actually coalesces
    # holding jobs a window can only delay completion
    assert rep1.latency_percentile(99) >= rep0.latency_percentile(99)


def test_fleet_config_validates_backend_knobs():
    with pytest.raises(ValueError, match="kernel-backend knobs"):
        FleetConfig(n_shards=2, batch_window_s=1e-4)
    with pytest.raises(ValueError, match="kernel-backend knobs"):
        FleetConfig(n_shards=2, calibration="x.json")
    with pytest.raises(ValueError, match="backend"):
        FleetConfig(n_shards=2, backend="mosaic")
    cfg = FleetConfig(n_shards=2, backend="kernel", batch_window_s=1e-4)
    d = cfg.to_dict()
    assert d["backend"] == "kernel"
    assert d["batch_window_us"] == pytest.approx(100.0)
    # analytic configs serialize exactly as before the backend axis
    assert "backend" not in FleetConfig(n_shards=2).to_dict()


def test_exec_cli_fields_validate():
    from repro.cli import exec_fields_from_args
    ns = types.SimpleNamespace(backend="analytic", batch_window_us=50.0,
                               calibration=None)
    with pytest.raises(ValueError, match="kernel-backend"):
        exec_fields_from_args(ns)
    ns = types.SimpleNamespace(backend="kernel", batch_window_us=50.0,
                               calibration=None)
    assert exec_fields_from_args(ns) == dict(
        backend="kernel", batch_window_s=pytest.approx(5e-5),
        calibration=None)


# ------------------------------------------------- calibration harness --

def test_calibrate_quick_produces_usable_table(tmp_path):
    from repro.exec.calibrate import measure_table
    t = measure_table(quick=True, iters=1)
    ops_seen = {e.op for e in t.entries}
    assert ops_seen == {"dist", "adc"}
    assert all(e.unit_s > 0 for e in t.entries)
    assert all(r["roofline_frac"] < 1.0 for r in t.meta["rooflines"])
    assert t.plan_seconds(1000, 500, 32, 8) > 0
    p = tmp_path / "t.json"
    t.save(str(p))
    # a measured-then-saved table is a valid --calibration input
    assert json.loads(p.read_text())["version"] == 1
    assert CalibrationTable.load(str(p)).dist_unit_s(32) > 0


# ------------------------------------------------------- window tuning --

def test_tune_batch_window_smoke():
    from repro.tuning import (WindowRecommendation, tune_batch_window,
                              EnvSpec, WorkloadSpec, resolve_storage)
    w = WorkloadSpec(n=2000, dim=32, dtype="float32", target_recall=0.9,
                     concurrency=8, k=10)
    env = EnvSpec(storage=resolve_storage("tos"), cache_bytes=0)
    rec = tune_batch_window(w, env, window_grid_us=(0.0, 500.0),
                            eval_n=400, nq=16, seed=0)
    assert isinstance(rec, WindowRecommendation)
    assert rec.window_us in (0.0, 500.0)
    assert len(rec.outcomes) == 2
    o0, o1 = rec.outcomes
    assert o0.mean_batch_jobs == 1.0 and o0.batches > 0
    assert o1.mean_batch_jobs >= o0.mean_batch_jobs
    assert {o.recall for o in rec.outcomes} == {o0.recall}
    d = rec.to_dict()
    assert d["recommendation"]["backend"] == "kernel"
    assert len(d["sweep"]) == 2
