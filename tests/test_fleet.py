"""Fleet serving: partitioning, routing, hedging, backpressure, scaling."""
import dataclasses

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              SearchParams)
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import (ClusterPartition, FleetConfig, GraphPartition,
                         merge_topk, partition_for_index, run_fleet)
from repro.serving.engine import run_workload
from repro.storage.spec import TOS
from repro.tuning import (EnvSpec, FleetPoint, WorkloadSpec,
                          resolve_storage, tune_fleet)


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4, seed=0))
    gi = GraphIndex.build(data, GraphIndexParams(
        R=24, L_build=48, build_passes=1, pq_dims=24, seed=0))
    return data, queries, gt, ci, gi


# ------------------------------------------------------------ partition --

def test_cluster_partition_balance_and_replication(setup):
    _, _, _, ci, _ = setup
    part = ClusterPartition.build(ci.meta.list_nbytes, n_shards=4,
                                  replication=2)
    assert part.bytes_imbalance < 1.25          # LPT keeps bytes even
    for li in range(ci.meta.n_lists):
        owners = part.owners(("list", li))
        assert len(owners) == 2
        assert len(set(owners)) == 2            # replicas on distinct shards
        assert all(0 <= s < 4 for s in owners)
    # deterministic
    part2 = ClusterPartition.build(ci.meta.list_nbytes, 4, 2)
    np.testing.assert_array_equal(part.owners_arr, part2.owners_arr)


def test_graph_partition_spreads_and_replicates(setup):
    _, _, _, _, gi = setup
    part = GraphPartition.build(gi.meta.n_data, n_shards=4, replication=2,
                                seed=0)
    assert part.bytes_imbalance < 1.2           # hash spreads evenly
    owners = part.owners(("node", 17))
    assert len(set(owners)) == 2
    # seed changes placement
    part2 = GraphPartition.build(gi.meta.n_data, 4, 2, seed=1)
    assert not np.array_equal(part.base, part2.base)


def test_partition_factory_and_validation(setup):
    _, _, _, ci, gi = setup
    assert partition_for_index(ci, 2, 1).kind == "cluster"
    assert partition_for_index(gi, 2, 1).kind == "graph"
    with pytest.raises(ValueError):
        ClusterPartition.build(ci.meta.list_nbytes, 2, 3)  # R > shards
    with pytest.raises(ValueError):
        GraphPartition.build(100, 0, 1)


# ---------------------------------------------------------------- merge --

def test_merge_topk_equals_global_topk():
    rng = np.random.default_rng(0)
    from repro.core.types import QueryMetrics, SearchResult
    ids = rng.permutation(100)
    d = rng.uniform(0, 1, 100).astype(np.float32)
    # split into 3 "shards", each returning its local top-10
    parts = []
    for chunk in np.array_split(np.arange(100), 3):
        o = np.argsort(d[chunk])[:10]
        parts.append(SearchResult(ids[chunk][o], d[chunk][o],
                                  QueryMetrics()))
    got_ids, got_d = merge_topk(parts, 10)
    order = np.argsort(d)[:10]
    np.testing.assert_array_equal(got_ids, ids[order])
    np.testing.assert_allclose(got_d, d[order])


# ------------------------------------------------------- single-shard ----

def test_one_shard_fleet_matches_single_engine(setup):
    """Acceptance: a 1-shard fleet reproduces the single-QueryEngine
    report (identical results; virtual-time QPS within tolerance)."""
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=16)
    mono = run_workload(ci, queries, p, _quiet(TOS), concurrency=8,
                        cache_policy="none")
    fleet = run_fleet(ci, queries, p, FleetConfig(
        n_shards=1, replication=1, storage=_quiet(TOS), concurrency=8,
        shard_concurrency=8, queue_depth=64))
    by_qid = {r.qid: r for r in mono.records}
    for rec in fleet.records:
        np.testing.assert_array_equal(rec.ids, by_qid[rec.qid].ids)
    assert fleet.qps == pytest.approx(mono.qps, rel=0.05)
    assert fleet.storage_bytes == mono.storage_bytes


def test_fleet_results_identical_to_direct_search(setup):
    """Sharding changes timing and placement, never results."""
    _, queries, _, ci, gi = setup
    p = SearchParams(k=10, nprobe=16)
    rep = run_fleet(ci, queries[:12], p, FleetConfig(
        n_shards=3, replication=2, storage=_quiet(TOS), concurrency=4))
    for rec in rep.records:
        direct = ci.search(queries[rec.qid], p)
        np.testing.assert_array_equal(rec.ids, direct.ids)
    pg = SearchParams(k=10, search_len=40, beamwidth=8)
    rep = run_fleet(gi, queries[:8], pg, FleetConfig(
        n_shards=3, replication=2, storage=_quiet(TOS), concurrency=4))
    for rec in rep.records:
        direct = gi.search(queries[rec.qid], pg)
        np.testing.assert_array_equal(rec.ids, direct.ids)


# ----------------------------------------------------------- behaviour ---

def test_fleet_deterministic(setup):
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=32)
    cfg = FleetConfig(n_shards=4, replication=2, storage=TOS,
                      concurrency=16, hedge=True, hedge_percentile=75.0,
                      seed=5)
    a = run_fleet(ci, queries, p, cfg)
    b = run_fleet(ci, queries, p, cfg)
    assert a.to_json() == b.to_json()


def test_qps_scales_with_shards(setup):
    """Acceptance: aggregate QPS rises monotonically 1 -> 4 shards at a
    fixed recall operating point (fixed nprobe => identical results)."""
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=64)
    qps = []
    for s in (1, 2, 4):
        rep = run_fleet(ci, queries, p, FleetConfig(
            n_shards=s, replication=min(2, s), storage=TOS,
            concurrency=32, shard_concurrency=8, queue_depth=64, seed=1))
        qps.append(rep.qps)
    assert qps[0] < qps[1] < qps[2]


def test_backpressure_sheds_and_recovers(setup):
    """Full admission queues shed submissions; retries mean no query is
    ever dropped and results stay complete."""
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=64)
    rep = run_fleet(ci, queries, p, FleetConfig(
        n_shards=2, replication=1, storage=TOS, concurrency=32,
        shard_concurrency=1, queue_depth=1, seed=1))
    assert rep.sheds_total > 0
    assert rep.shed_rate > 0
    assert len(rep.records) == len(queries)
    assert all((r.ids >= 0).all() for r in rep.records)
    assert sum(r.shed_retries for r in rep.records) > 0


def test_hedging_fires_and_preserves_results(setup):
    """With a heavy TTFB tail, hedge timers fire, some hedges win, and
    results are unchanged (first completion wins, content identical)."""
    _, queries, gt, ci, _ = setup
    p = SearchParams(k=10, nprobe=64)
    heavy = dataclasses.replace(TOS, ttfb_sigma=1.1)
    base = dict(n_shards=4, replication=2, storage=heavy, concurrency=4,
                shard_concurrency=8, queue_depth=64, seed=3,
                hedge_min_samples=16)
    off = run_fleet(ci, queries, p, FleetConfig(**base))
    on = run_fleet(ci, queries, p, FleetConfig(
        hedge=True, hedge_percentile=70.0, **base))
    assert on.hedges_launched > 0
    assert 0 <= on.hedge_wins <= on.hedges_launched
    assert on.recall_against(gt) == off.recall_against(gt)
    # hedging attacks exactly the slow-replica tail the paper's cold
    # TTFB distribution produces
    assert on.latency_percentile(95) < off.latency_percentile(95)


def test_fleet_cache_reduces_storage_traffic(setup):
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=32)
    stream = np.concatenate([queries, queries])
    cold = run_fleet(ci, stream, p, FleetConfig(
        n_shards=2, replication=1, storage=_quiet(TOS), concurrency=8))
    warm = run_fleet(ci, stream, p, FleetConfig(
        n_shards=2, replication=1, storage=_quiet(TOS), concurrency=8,
        cache_bytes=1 << 30, cache_policy="slru"))
    assert warm.hit_rate > 0.3
    assert warm.storage_bytes < cold.storage_bytes


def test_fleet_config_validation():
    with pytest.raises(ValueError):
        FleetConfig(n_shards=0)
    with pytest.raises(ValueError):
        FleetConfig(n_shards=2, replication=3)
    with pytest.raises(ValueError):
        FleetConfig(cache_policy="pinned")
    with pytest.raises(ValueError):
        FleetConfig(hedge=True, hedge_percentile=30.0)


# -------------------------------------------------------------- tuning ---

def test_tune_fleet_picks_larger_fleet_for_higher_target():
    w = WorkloadSpec(n=1_000_000, dim=96, target_recall=0.9,
                     concurrency=16)
    env = EnvSpec(storage=resolve_storage("tos"))
    modest = tune_fleet(w, env, target_speedup=1.05,
                        shard_grid=(1, 2, 4), replica_grid=(1, 2),
                        eval_n=800, nq=32)
    ambitious = tune_fleet(w, env, target_speedup=1.8,
                           shard_grid=(1, 2, 4), replica_grid=(1, 2),
                           eval_n=800, nq=32)
    assert modest.feasible
    m = modest.point.n_shards * modest.point.replication
    a = ambitious.point.n_shards * ambitious.point.replication
    assert a >= m
    if ambitious.feasible:
        assert ambitious.speedup >= 1.8


def test_fleet_point_validation():
    with pytest.raises(ValueError):
        FleetPoint(0)
    with pytest.raises(ValueError):
        FleetPoint(2, replication=4)
