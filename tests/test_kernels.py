"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the kernel bodies execute exactly as
they would tile on TPU; Mosaic lowering is exercised on real hardware).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import adc_lookup_ref, l2_distance_ref, l2_topk_ref


def _mk(q, n, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    if dtype == "int8":
        qs = rng.integers(-127, 128, size=(q, d)).astype(np.int8)
        xs = rng.integers(-127, 128, size=(n, d)).astype(np.int8)
    elif dtype == "bfloat16":
        qs = rng.normal(size=(q, d)).astype(jnp.bfloat16)
        xs = rng.normal(size=(n, d)).astype(jnp.bfloat16)
    else:
        qs = rng.normal(size=(q, d)).astype(np.float32)
        xs = rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(qs), jnp.asarray(xs)


# ------------------------------------------------------------- distance --

@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("q,n,d", [
    (4, 16, 8),          # tiny, everything padded
    (128, 256, 256),     # exact tile multiples
    (100, 300, 96),      # deep-analog dims, ragged tiles
    (7, 513, 960),       # gist-analog dims, ragged everywhere
])
def test_l2_distance_matches_ref(dtype, q, n, d):
    qs, xs = _mk(q, n, d, dtype)
    got = ops.l2_distance(qs, xs, interpret=True)
    want = l2_distance_ref(qs, xs)
    assert got.shape == (q, n)
    if dtype == "int8":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        rtol = 2e-2 if dtype == "bfloat16" else 1e-5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=rtol, atol=1e-2)


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 64)])
def test_l2_distance_block_shape_independent(blocks):
    bq, bn, bd = blocks
    qs, xs = _mk(50, 130, 100, "float32")
    got = ops.l2_distance(qs, xs, interpret=True,
                          block_q=bq, block_n=bn, block_d=bd)
    want = l2_distance_ref(qs, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


# ------------------------------------------------------------------ ADC --

@pytest.mark.parametrize("n,m", [(10, 8), (1024, 48), (2000, 112), (3, 120)])
def test_adc_lookup_matches_ref(n, m):
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, size=(n, m)).astype(np.uint8))
    table = jnp.asarray(rng.random((m, 256)).astype(np.float32))
    got = ops.adc_lookup(codes, table, interpret=True)
    want = adc_lookup_ref(codes, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_adc_lookup_matches_pq_module():
    """Kernel agrees with the ProductQuantizer host path end-to-end."""
    from repro.core.pq import train_pq
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 96)).astype(np.float32)
    pq = train_pq(x, m=48, iters=4, seed=0)
    codes = pq.encode(x)
    table = pq.adc_table(x[0])
    got = ops.adc_lookup(jnp.asarray(codes), jnp.asarray(table),
                         interpret=True)
    want = pq.adc_lookup(codes, table)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)


# ----------------------------------------------------------- fused topk --

@pytest.mark.parametrize("q,n,d,k", [
    (4, 64, 32, 5),
    (128, 1024, 96, 10),
    (33, 700, 960, 10),
    (1, 2048, 128, 20),
])
def test_l2_topk_matches_ref(q, n, d, k):
    qs, xs = _mk(q, n, d, "float32")
    vals, ids = ops.l2_topk(qs, xs, k, interpret=True)
    rvals, rids = l2_topk_ref(qs, xs, k)
    assert vals.shape == (q, k) and ids.shape == (q, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               rtol=1e-4, atol=1e-3)
    # ids may differ only on exact distance ties; check via distances
    d_by_id = np.take_along_axis(
        np.asarray(l2_distance_ref(qs, xs)), np.asarray(ids), axis=1)
    np.testing.assert_allclose(d_by_id, np.asarray(rvals),
                               rtol=1e-4, atol=1e-3)


def test_l2_topk_ids_unique_and_sorted():
    qs, xs = _mk(16, 512, 64, "float32", seed=3)
    vals, ids = ops.l2_topk(qs, xs, 10, interpret=True)
    vals, ids = np.asarray(vals), np.asarray(ids)
    for r in range(16):
        assert len(np.unique(ids[r])) == 10
        assert (np.diff(vals[r]) >= -1e-6).all()


def test_l2_topk_block_sweep():
    qs, xs = _mk(40, 333, 100, "float32", seed=4)
    rvals, _ = l2_topk_ref(qs, xs, 10)
    for bq, bn in [(16, 64), (64, 128), (128, 512)]:
        vals, _ = ops.l2_topk(qs, xs, 10, interpret=True,
                              block_q=bq, block_n=bn)
        np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                                   rtol=1e-4, atol=1e-3)
