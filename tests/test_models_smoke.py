"""Per-arch smoke tests: reduced same-family configs, one forward/train
step on CPU, asserting output shapes and no NaNs (assignment requirement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, smoke
from repro.configs.base import ShapeConfig
from repro.models.model import LM

B, S = 2, 32


def _batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[0], (B, seq, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, seq), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(ks[1], (B, seq), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_loss_finite(arch, models):
    cfg = smoke(ARCHS[arch])
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key)
    models[arch] = (lm, params)
    batch = _batch(cfg, key)
    loss = jax.jit(lm.loss)(params, batch)
    assert np.isfinite(float(loss))
    # loss near ln(vocab) at init (calibrated logits)
    assert float(loss) < 3 * np.log(cfg.vocab) + 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_grad_finite(arch, models):
    lm, params = models[arch]
    cfg = lm.cfg
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(lm.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch, models):
    """Greedy decode after prefill must match the teacher-forced forward
    logits (same positions) — validates every cache implementation."""
    lm, params = models[arch]
    cfg = lm.cfg
    batch = _batch(cfg, jax.random.PRNGKey(2))
    full_logits = jax.jit(lm.logits)(params, batch)
    prompt_len = S - 4

    def cut(b, sl):
        out = dict(b)
        if cfg.family == "audio":
            out["frames"] = b["frames"][:, sl]
        else:
            out["tokens"] = b["tokens"][:, sl]
        out.pop("labels", None)
        return out

    logits_p, caches = jax.jit(lm.prefill)(params, cut(batch,
                                                       slice(0, prompt_len)))
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]),
        np.asarray(full_logits[:, prompt_len - 1]), rtol=2e-2, atol=2e-2)

    # grow caches to full capacity for decoding
    caches = jax.tree.map(jnp.asarray, caches)
    caches = _grow_caches(lm, caches, prompt_len, S)
    step = jax.jit(lm.decode_step)
    for t in range(prompt_len, S):
        bt = cut(batch, slice(t, t + 1))
        logits_t, caches = step(params, bt, jnp.int32(t), caches)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(full_logits[:, t]),
            rtol=3e-2, atol=3e-2)


def _grow_caches(lm, caches, cur_len, capacity):
    """Pad attention KV caches from prefill length to decode capacity."""
    cfg = lm.cfg
    window = cfg.local_window if cfg.block_pattern else 0

    def grow(leaf):
        if (hasattr(leaf, "ndim") and leaf.ndim >= 4
                and leaf.shape[-2] == cfg.n_kv_heads):
            seq_ax = leaf.ndim - 3
            if (cfg.family == "vlm"
                    and leaf.shape[seq_ax] == cfg.n_frontend_tokens):
                return leaf          # cross-attn image K/V: fixed length
            cap = min(capacity, window) if window else capacity
            pad = cap - leaf.shape[seq_ax]
            if pad > 0:
                widths = [(0, 0)] * leaf.ndim
                widths[seq_ax] = (0, pad)
                return jnp.pad(leaf, widths)
        return leaf

    return jax.tree.map(grow, caches)


def test_n_params_sane():
    # full configs must be in the advertised ballpark
    approx = {
        "mamba2-1.3b": (0.9e9, 2.0e9),
        "gemma-2b": (2.0e9, 3.3e9),
        "starcoder2-7b": (6e9, 9e9),
        "internlm2-20b": (17e9, 24e9),
        "qwen3-32b": (28e9, 38e9),
        "dbrx-132b": (110e9, 145e9),
        # the assigned sheet's dims (48L x 64e x d_ff 1408) give ~29B total
        # (the HF Moonlight-16B uses 27 layers; the assignment overrides)
        "moonshot-v1-16b-a3b": (24e9, 32e9),
    }
    for name, (lo, hi) in approx.items():
        n = ARCHS[name].n_params()
        assert lo <= n <= hi, (name, n)


def test_moe_active_params():
    cfg = ARCHS["moonshot-v1-16b-a3b"]
    act = cfg.n_active_params()
    assert act < 0.4 * cfg.n_params()     # A3B: ~3B active of 16B
    assert 2e9 <= act <= 5e9
