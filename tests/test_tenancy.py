"""repro.tenancy: cache-sharing policies, fair-share windows, per-tenant
slices, interference bounds, golden parity, cache-split tuning.

The acceptance pair:

* a single-tenant ``shared``-policy fleet run reproduces
  ``tests/data/golden_fleet_prerefactor.json`` bit-exactly (the
  tenancy layer extends the golden-parity chain);
* interference regressions — under ``weighted`` a bursty tenant cannot
  push a steady tenant's p99 past the documented bound
  (``docs/tenancy.md``: 1.5x solo); under ``static`` a tenant's hit
  rate is independent of its neighbours.
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig
from repro.tenancy import (TENANT_CACHE_POLICIES, MultiTenantRouter,
                           SharedTenantCache, StaticTenantCache, Tenant,
                           TenantSpec, WeightedTenantCache,
                           fair_share_windows, load_tenant_specs,
                           make_tenant_cache, materialize_tenant,
                           run_tenant_fleet)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")

#: the documented weighted-policy interference bound (docs/tenancy.md)
WEIGHTED_INTERFERENCE_BOUND = 1.5


# ------------------------------------------------------------- policies --

def test_policy_factory_and_validation():
    w = {0: 1.0, 1: 1.0}
    assert make_tenant_cache("shared", 0, w) is None
    for pol, cls in (("shared", SharedTenantCache),
                     ("static", StaticTenantCache),
                     ("weighted", WeightedTenantCache)):
        assert isinstance(make_tenant_cache(pol, 1 << 20, w), cls)
    with pytest.raises(ValueError):
        make_tenant_cache("lru", 1 << 20, w)
    with pytest.raises(ValueError):
        StaticTenantCache(1 << 20, {0: 0.0})


def test_static_partitions_quota_and_isolation():
    c = StaticTenantCache(1000, {0: 3.0, 1: 1.0})
    assert c.parts[0].capacity + c.parts[1].capacity == 1000
    assert c.parts[0].capacity == 750
    # tenant 0 filling its partition cannot evict tenant 1's entries
    c.put((1, "k"), 200)
    for i in range(20):
        c.put((0, "x", i), 100)
    assert c.get((1, "k"))
    assert c.tenant_used_bytes(0) <= c.tenant_quota_bytes(0)
    assert c.tenant_used_bytes(1) == 200


def test_shared_policy_is_one_slru():
    c = SharedTenantCache(300, {0: 1.0, 1: 1.0})
    c.put((0, "a"), 200)
    c.put((1, "b"), 200)          # evicts tenant 0's probation entry
    assert not c.get((0, "a"))
    assert c.get((1, "b"))
    assert c.tenant_used_bytes(0) == 0
    assert c.tenant_used_bytes(1) == 200


def test_weighted_reallocation_moves_quota_toward_ghost_pressure():
    c = WeightedTenantCache(1000, {0: 1.0, 1: 1.0},
                            realloc_every=64, step_frac=0.1)
    q0 = c.parts[0].capacity
    # tenant 0 cycles a working set twice its quota (heavy ghost hits);
    # tenant 1 is idle
    for round_ in range(10):
        for i in range(10):
            key = (0, "k", i)
            if not c.get(key):
                c.put(key, 100)
    assert c.reallocations > 0
    assert c.parts[0].capacity > q0
    assert c.parts[0].capacity + c.parts[1].capacity == 1000
    # floors hold: tenant 1 keeps at least min_frac of its fair share
    assert c.parts[1].capacity >= c.floors[1]


def test_weighted_quota_sum_invariant_under_churn():
    rng = np.random.default_rng(0)
    c = WeightedTenantCache(4096, {0: 1.0, 1: 2.0, 2: 1.0},
                            realloc_every=32)
    total0 = sum(p.capacity for p in c.parts.values())
    for _ in range(2000):
        tid = int(rng.integers(0, 3))
        key = (tid, int(rng.integers(0, 40)))
        op = rng.integers(0, 4)
        if op == 0:
            c.put(key, int(rng.integers(1, 400)))
        elif op == 1:
            c.get(key)
        elif op == 2:
            c.remove(key)
        else:
            c.invalidate(key)
        assert sum(p.capacity for p in c.parts.values()) == total0
        for p in c.parts.values():
            assert p.used_bytes <= p.capacity


def test_fair_share_windows():
    assert fair_share_windows(8, [1.0, 1.0]) == [4, 4]
    assert fair_share_windows(8, [3.0, 1.0]) == [6, 2]
    assert fair_share_windows(2, [0.1, 9.9]) == [1, 1]  # floor at 1
    # never oversubscribes: windows sum to exactly the fleet window
    assert sum(fair_share_windows(8, [1.0, 1.0, 1.0])) == 8
    assert sum(fair_share_windows(7, [1.0, 2.0, 4.0])) == 7
    # unless floors force it (more tenants than slots)
    assert fair_share_windows(2, [1.0, 1.0, 1.0]) == [1, 1, 1]
    with pytest.raises(ValueError):
        fair_share_windows(8, [0.0])


# ----------------------------------------------------------- spec/json ---

def test_tenant_spec_validation_and_json(tmp_path):
    with pytest.raises(ValueError):
        TenantSpec(name="x", index="flat")
    with pytest.raises(ValueError):
        TenantSpec(name="x", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec(name="x", scenario="storm")
    specs = [TenantSpec(name="a", n=300), TenantSpec(name="b", n=300)]
    path = tmp_path / "tenants.json"
    path.write_text(json.dumps([s.to_dict() for s in specs]))
    loaded = load_tenant_specs(str(path))
    assert [s.name for s in loaded] == ["a", "b"]
    path.write_text(json.dumps([specs[0].to_dict(), specs[0].to_dict()]))
    with pytest.raises(ValueError):
        load_tenant_specs(str(path))
    path.write_text(json.dumps([dict(name="a", botnet=1)]))
    with pytest.raises(ValueError):
        load_tenant_specs(str(path))


# -------------------------------------------------------- golden parity --

def test_single_tenant_shared_reproduces_golden():
    """Acceptance: the tenancy path with one tenant under the shared
    policy reproduces the pre-tenancy golden fleet reports bit-exactly."""
    golden = json.load(open(GOLDEN_PATH))
    data, queries = make_dataset(scaled(DEEP_ANALOG, 1200, 32))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64,
                              seed=0),
        four_shard=FleetConfig(n_shards=4, replication=2, concurrency=16,
                               shard_concurrency=4, queue_depth=16,
                               hedge=True, hedge_percentile=75.0, seed=5))
    for name, cfg in configs.items():
        index = ClusterIndex.build(data, ClusterIndexParams(
            kmeans_iters=4, seed=0))
        tenant = Tenant(spec=TenantSpec(name="solo"), index=index,
                        queries=queries, params=p)
        rep = run_tenant_fleet([tenant], cfg, "shared")
        g = golden[name]
        assert rep.fleet.wall_time_s == pytest.approx(
            g["wall_time_s"], rel=1e-9, abs=1e-12)
        assert rep.fleet.qps == pytest.approx(g["qps"], rel=1e-9)
        h = hashlib.sha256()
        for r in sorted(rep.tenants[0].records, key=lambda r: r.qid):
            h.update(np.asarray(r.qid).tobytes())
            h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
        assert h.hexdigest() == g["ids_sha256"]


# ------------------------------------------------------------ behaviour --

def _steady_spec():
    return TenantSpec(name="steady", n=600, dim=32, n_queries=32,
                      nprobe=8, scenario="trace", rate_qps=250.0,
                      n_arrivals=128, zipf_a=1.4, slo_ms=60, weight=1.0)


def _bursty_spec():
    return TenantSpec(name="bursty", n=1200, dim=32, n_queries=24,
                      nprobe=64, scenario="burst", rate_qps=250.0,
                      n_arrivals=128, burst_factor=10.0,
                      burst_start_s=0.1, burst_len_s=0.3, slo_ms=150,
                      weight=1.0)


def _contended_cfg():
    return FleetConfig(n_shards=2, replication=2, concurrency=6,
                       cache_bytes=64 * 1024, cache_policy="slru",
                       seed=3)


@pytest.fixture(scope="module")
def interference():
    """One solo baseline + one shared-fleet run per policy (the solo run
    is policy-independent: a lone tenant owns the whole budget)."""
    cfg = _contended_cfg()

    def mk():
        return [materialize_tenant(s, base_seed=cfg.seed, tid=i)
                for i, s in enumerate((_steady_spec(), _bursty_spec()))]

    steady_solo = materialize_tenant(_steady_spec(), base_seed=cfg.seed,
                                     tid=0)
    solo = run_tenant_fleet([steady_solo], cfg, "shared")
    solo_p99 = solo.tenants[0].sojourn_percentile(99)
    reports = {}
    for pol in TENANT_CACHE_POLICIES:
        rep = run_tenant_fleet(mk(), cfg, pol)
        rep.tenant("steady").solo_p99_s = solo_p99
        reports[pol] = rep
    return reports


def test_weighted_bounds_bursty_interference(interference):
    """Satellite acceptance: under ``weighted`` the bursty tenant cannot
    push the steady tenant's p99 past the documented bound, and the
    isolation is strictly better than free-for-all sharing."""
    weighted = interference["weighted"].tenant("steady")
    shared = interference["shared"].tenant("steady")
    assert weighted.interference_ratio <= WEIGHTED_INTERFERENCE_BOUND
    assert weighted.interference_ratio < shared.interference_ratio
    assert interference["weighted"].reallocations > 0


def test_shared_policy_shows_cache_pollution(interference):
    """The scenario is a real stressor: free sharing lets the scan
    tenant pollute the steady tenant's hot set (hit rate drops vs
    static partitions)."""
    assert interference["static"].tenant("steady").hit_rate > \
        interference["shared"].tenant("steady").hit_rate


def test_weighted_dominates_static_on_aggregate_goodput(interference):
    """Acceptance: adaptive quotas strictly beat static partitions on
    aggregate goodput for the skewed two-tenant scenario."""
    assert interference["weighted"].aggregate_goodput_qps > \
        interference["static"].aggregate_goodput_qps


def test_static_hit_rates_independent_across_tenants():
    """Satellite acceptance: with static partitions, tenant A's hit rate
    is *exactly* independent of who shares the fleet (B swapped for a
    very different B' leaves A's cache op sequence untouched)."""
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=2,
                      cache_bytes=96 * 1024, cache_policy="slru", seed=1)
    a = TenantSpec(name="a", n=500, dim=32, n_queries=24, nprobe=8,
                   weight=1.0)
    b = TenantSpec(name="b", n=400, dim=32, n_queries=16, nprobe=8,
                   weight=1.0)
    b_prime = TenantSpec(name="b", n=800, dim=48, n_queries=32,
                         nprobe=48, weight=1.0)
    r1 = run_tenant_fleet([a, b], cfg, "static")
    r2 = run_tenant_fleet([a, b_prime], cfg, "static")
    assert r1.tenant("a").hit_rate == r2.tenant("a").hit_rate
    assert r1.tenant("a").bytes_read == r2.tenant("a").bytes_read
    # ... and under free sharing the neighbour *does* bleed through
    s1 = run_tenant_fleet([a, b], cfg, "shared")
    s2 = run_tenant_fleet([a, b_prime], cfg, "shared")
    assert s1.tenant("a").hit_rate != s2.tenant("a").hit_rate


def test_multi_tenant_run_deterministic_and_results_exact():
    """Replay determinism + results equal direct per-tenant search."""
    cfg = FleetConfig(n_shards=2, replication=2, concurrency=8,
                      cache_bytes=1 << 20, cache_policy="slru", seed=0)
    specs = [TenantSpec(name="c", n=500, dim=32, n_queries=16, nprobe=12),
             TenantSpec(name="g", n=400, dim=32, n_queries=12,
                        index="graph", search_len=24, beamwidth=4)]
    a = run_tenant_fleet(specs, cfg, "weighted")
    b = run_tenant_fleet(specs, cfg, "weighted")
    assert a.to_json() == b.to_json()
    # sharing the fleet changes timing, never content
    tenants = [materialize_tenant(s, base_seed=cfg.seed, tid=i)
               for i, s in enumerate(specs)]
    rep = run_tenant_fleet(tenants, cfg, "weighted")
    for sl, t in zip(rep.tenants, tenants):
        for r in sl.records:
            direct = t.index.search(t.queries[r.qid], t.params)
            np.testing.assert_array_equal(r.ids, direct.ids)


def test_per_tenant_windows_are_fair_shares():
    cfg = FleetConfig(n_shards=1, replication=1, concurrency=9, seed=0)
    specs = [TenantSpec(name="big", n=300, dim=16, n_queries=8,
                        nprobe=4, weight=2.0),
             TenantSpec(name="small", n=300, dim=16, n_queries=8,
                        nprobe=4, weight=1.0)]
    rep = run_tenant_fleet(specs, cfg, "shared")
    assert rep.tenant("big").window == 6
    assert rep.tenant("small").window == 3


def test_multi_tenant_router_validation():
    cfg = FleetConfig(n_shards=1, replication=1)
    with pytest.raises(ValueError):
        MultiTenantRouter([], cfg)
    t = materialize_tenant(TenantSpec(name="a", n=300, dim=16,
                                      n_queries=8), 0, 0)
    with pytest.raises(ValueError):
        MultiTenantRouter([t], cfg, cache_policy="arc")


def test_rw_tenant_applies_updates_in_shared_fleet():
    """A tenant with a write stream ingests through the shared fleet
    (its own delta tier + compaction), and its deletes are honoured."""
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=4, seed=2)
    specs = [TenantSpec(name="rw", n=500, dim=32, n_queries=16, nprobe=12,
                        scenario="rw", write_rate_qps=600.0, n_updates=80,
                        delete_frac=0.3, n_arrivals=48, delta_kb=4.0),
             TenantSpec(name="ro", n=400, dim=32, n_queries=12, nprobe=8)]
    tenants = [materialize_tenant(s, base_seed=cfg.seed, tid=i)
               for i, s in enumerate(specs)]
    stream = tenants[0].updates
    assert stream is not None and len(stream) == 80
    rep = run_tenant_fleet(tenants, cfg, "shared")
    rw = rep.tenant("rw")
    assert rw.ingest is not None and rw.ingest["ops_delivered"] >= 80
    assert rw.ingest["flushes"] > 0
    assert rep.tenant("ro").ingest is None
    t_end = max(op.t for op in stream.ops)
    dead = {op.id for op in stream.ops if op.kind == "delete"}
    reborn = {op.id for op in stream.ops if op.kind == "insert"}
    for r in rw.records:
        if r.start_t > t_end:
            assert not set(int(i) for i in r.ids) & (dead - reborn)


# --------------------------------------------------------------- tuning --

def test_tune_cache_split_screen_and_refine():
    from repro.tuning import (enumerate_splits, screen_cache_splits,
                              tune_cache_split)
    from repro.tuning.tenancy import CacheSplit
    with pytest.raises(ValueError):
        CacheSplit((0.5, 0.6))
    splits = enumerate_splits(2, steps=4)
    assert len(splits) == 3              # 1/4..3/4
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8,
                      cache_bytes=96 * 1024, cache_policy="slru", seed=0)
    specs = [TenantSpec(name="hot", n=500, dim=32, n_queries=32,
                        nprobe=8),
             TenantSpec(name="cold", n=900, dim=32, n_queries=16,
                        nprobe=32)]
    tenants = [materialize_tenant(s, base_seed=0, tid=i)
               for i, s in enumerate(specs)]
    preds = screen_cache_splits(tenants, cfg.cache_bytes, steps=4)
    assert preds[0].miss_bytes_per_s <= preds[-1].miss_bytes_per_s
    rec = tune_cache_split(specs, cfg, steps=4, refine_top=2)
    assert abs(sum(rec.split.fractions) - 1.0) < 1e-9
    assert len(rec.outcomes) == 2
    best = max(o.aggregate_goodput_qps for o in rec.outcomes)
    assert rec.outcomes[0].aggregate_goodput_qps <= best + 1e-9
    with pytest.raises(ValueError):
        tune_cache_split(specs[:1], cfg)


def test_che_approximation_monotone_and_exact_limits():
    from repro.tuning import che_hit_rate
    prof = {("k", i): [100, (i % 5) + 1] for i in range(50)}
    sizes = [0, 500, 1500, 3000, 5000]
    hits = [che_hit_rate(prof, c) for c in sizes]
    assert hits[0] == 0.0
    assert hits[-1] == 1.0               # cache >= working set
    assert all(hits[i] <= hits[i + 1] + 1e-12
               for i in range(len(hits) - 1))
