"""Property-based cache byte accounting (satellite of the tenancy PR).

Invariant under arbitrary interleavings of put/get/remove/invalidate:

* an SLRU's byte counters exactly mirror its segment contents — no leak
  (counter > contents) and no double-count (counter < contents) ever;
* used bytes never exceed capacity; no key sits in both segments;
* a PinnedCache never accounts bytes and its membership matches its
  hit behaviour;
* partitioned tenant caches (static/weighted) never exceed any
  tenant's quota, and the weighted policy's reallocation conserves the
  total byte budget exactly;
* shrinking a cache below its protected-segment usage spills across
  *both* segments and satisfies ``used_bytes <= capacity`` immediately
  on return — never deferred to the next access;
* invalidation is neither a hit nor a miss: ``remove``/``invalidate``
  leave the ``(hits, misses)`` counters untouched on every cache class
  (SLRU, pinned, and all partitioned tenant assemblies), so compaction
  churn can never masquerade as workload locality change.

The generator runs on seeded numpy randomness so the sweep always
executes; when ``hypothesis`` is installed the same checker is also
driven by its shrinking engine.
"""
import numpy as np
import pytest

from repro.cache.slru import PinnedCache, SLRUCache
from repro.tenancy.policy import (SharedTenantCache, StaticTenantCache,
                                  WeightedTenantCache)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEYS = [("list", i) for i in range(12)] + [("node", i) for i in range(12)]
OPS = ("put", "get", "remove", "invalidate")


def check_slru_invariants(cache: SLRUCache) -> None:
    assert cache.probation_bytes == sum(cache.probation.values())
    assert cache.protected_bytes == sum(cache.protected.values())
    assert cache.used_bytes == (cache.probation_bytes
                                + cache.protected_bytes)
    assert cache.used_bytes <= cache.capacity
    assert not set(cache.probation) & set(cache.protected)
    assert all(v >= 0 for v in cache.probation.values())
    assert all(v >= 0 for v in cache.protected.values())


def cache_stats(cache) -> tuple[int, int]:
    """``(hits, misses)`` for any cache class, summing partitions."""
    parts = getattr(cache, "parts", None)
    if parts is not None:
        return (sum(p.hits for p in parts.values()),
                sum(p.misses for p in parts.values()))
    inner = getattr(cache, "inner", None)
    if inner is not None:
        return (inner.hits, inner.misses)
    return (cache.hits, cache.misses)


def apply_slru_ops(cache: SLRUCache, ops) -> None:
    """Run an op sequence, checking invariants after every step."""
    for op, key, nbytes in ops:
        if op == "put":
            cache.put(key, nbytes)
        elif op == "get":
            cache.get(key)
        elif op == "remove":
            stats = cache_stats(cache)
            freed = cache.remove(key)
            assert freed >= 0
            assert cache_stats(cache) == stats, \
                "remove must be neither a hit nor a miss"
        else:
            stats = cache_stats(cache)
            cache.invalidate(key)
            assert cache_stats(cache) == stats, \
                "invalidate must be neither a hit nor a miss"
        check_slru_invariants(cache)


def random_ops(rng: np.random.Generator, n: int, max_bytes: int = 400):
    out = []
    for _ in range(n):
        op = OPS[int(rng.integers(0, len(OPS)))]
        key = KEYS[int(rng.integers(0, len(KEYS)))]
        out.append((op, key, int(rng.integers(1, max_bytes))))
    return out


# ----------------------------------------------------------- SLRU sweep --

@pytest.mark.parametrize("seed", range(12))
def test_slru_byte_accounting_random_interleavings(seed):
    rng = np.random.default_rng(seed)
    capacity = int(rng.integers(1, 12)) * 100
    cache = SLRUCache(capacity)
    apply_slru_ops(cache, random_ops(rng, 400))
    # full teardown returns every byte
    for key in list(cache.probation) + list(cache.protected):
        cache.remove(key)
        check_slru_invariants(cache)
    assert cache.used_bytes == 0


def test_slru_resize_keeps_accounting_exact():
    rng = np.random.default_rng(7)
    cache = SLRUCache(1000)
    for i, (op, key, nbytes) in enumerate(random_ops(rng, 300)):
        getattr(cache, op)(key, nbytes) if op == "put" else \
            getattr(cache, op)(key)
        if i % 25 == 0:
            cache.set_capacity(int(rng.integers(0, 15)) * 100)
        check_slru_invariants(cache)


def test_slru_shrink_below_protected_spills_both_segments():
    """A resize below the protected segment's usage must land within
    budget *on return* — demoting protected overflow into probation and
    evicting LRU-first across the combined spill, not just probation."""
    cache = SLRUCache(1000)
    for i in range(8):
        cache.put(("k", i), 100)
        cache.get(("k", i))              # promote into protected
    cache.put(("p", 0), 100)
    cache.put(("p", 1), 100)
    assert cache.protected_bytes == 800 and cache.probation_bytes == 200
    evicted = []
    cache.on_evict = lambda k, s: evicted.append(k)
    cache.set_capacity(300)              # well below protected usage
    assert cache.used_bytes <= 300       # immediately, not eventually
    check_slru_invariants(cache)
    # the spill crossed both segments: original probation entries AND
    # demoted protected entries were evicted
    assert any(k[0] == "p" for k in evicted)
    assert any(k[0] == "k" for k in evicted)
    # survivors are the most-recently-used protected entries, within the
    # shrunken protected ceiling
    assert cache.protected_bytes <= cache.protected_cap
    assert ("k", 7) in cache
    cache.set_capacity(0)                # degenerate shrink: drop all
    assert cache.used_bytes == 0 and len(cache) == 0
    check_slru_invariants(cache)


@pytest.mark.parametrize("seed", range(8))
def test_slru_shrink_below_protected_property(seed):
    """Randomised variant: promote-heavy fill, then shrink to targets
    scattered below protected usage (including 0 and sub-entry sizes)."""
    rng = np.random.default_rng(seed)
    cache = SLRUCache(int(rng.integers(5, 20)) * 100)
    for op, key, nbytes in random_ops(rng, 150):
        if op == "put":
            cache.put(key, nbytes)
            cache.get(key)               # immediate re-reference: promote
        else:
            cache.get(key)
    for target in sorted(rng.integers(0, max(cache.protected_bytes, 1),
                                      size=4), reverse=True):
        cache.set_capacity(int(target))
        assert cache.used_bytes <= cache.capacity
        assert cache.protected_bytes <= cache.protected_cap
        check_slru_invariants(cache)


def test_slru_oversize_put_is_rejected_without_accounting_drift():
    cache = SLRUCache(100)
    cache.put("big", 101)
    assert "big" not in cache and cache.used_bytes == 0
    cache.put("fits", 100)
    assert cache.used_bytes == 100
    check_slru_invariants(cache)


# --------------------------------------------------------------- pinned --

@pytest.mark.parametrize("seed", range(6))
def test_pinned_membership_matches_hits(seed):
    rng = np.random.default_rng(seed)
    pinned_keys = {KEYS[i] for i in range(0, len(KEYS), 3)}
    cache = PinnedCache(set(pinned_keys))
    for op, key, nbytes in random_ops(rng, 200):
        if op == "put":
            cache.put(key, nbytes)          # fixed content: no-op
        elif op == "get":
            assert cache.get(key) == (key in cache.keys)
        elif op == "remove":
            stats = cache_stats(cache)
            assert cache.remove(key) == 0   # pinned carries no bytes
            assert cache_stats(cache) == stats
        else:
            stats = cache_stats(cache)
            cache.invalidate(key)
            assert cache_stats(cache) == stats
        assert cache.used_bytes == 0
        assert cache.keys <= pinned_keys    # unpinning only shrinks


# ---------------------------------------------------- tenant partitions --

def tenant_ops(rng: np.random.Generator, n: int, n_tenants: int):
    out = []
    for _ in range(n):
        op = OPS[int(rng.integers(0, len(OPS)))]
        key = (int(rng.integers(0, n_tenants)),
               "list", int(rng.integers(0, 16)))
        out.append((op, key, int(rng.integers(1, 400))))
    return out


def apply_tenant_op(cache, op, key, nbytes) -> None:
    """One op against a tenant assembly, asserting the stats contract
    (invalidation paths never move the hit/miss counters)."""
    if op == "put":
        cache.put(key, nbytes)
    elif op == "get":
        cache.get(key)
    else:
        stats = cache_stats(cache)
        (cache.remove if op == "remove" else cache.invalidate)(key)
        assert cache_stats(cache) == stats, \
            f"{op} must be neither a hit nor a miss on {cache.policy}"


def check_partition_invariants(cache, total: int) -> None:
    assert sum(p.capacity for p in cache.parts.values()) == total
    for p in cache.parts.values():
        check_slru_invariants(p)
        assert p.used_bytes <= p.capacity


@pytest.mark.parametrize("cls", [StaticTenantCache, WeightedTenantCache])
@pytest.mark.parametrize("seed", range(6))
def test_partitioned_caches_never_exceed_quota(cls, seed):
    rng = np.random.default_rng(seed)
    total = 2000
    weights = {0: 1.0, 1: 2.0, 2: 0.5}
    cache = cls(total, weights)
    for op, key, nbytes in tenant_ops(rng, 500, 3):
        apply_tenant_op(cache, op, key, nbytes)
        check_partition_invariants(cache, total)


# ------------------------------------------------ invalidation contract --

@pytest.mark.parametrize("make", [
    lambda: SLRUCache(1000),
    lambda: PinnedCache({(0, "list", i) for i in range(4)}),
    lambda: SharedTenantCache(2000, {0: 1.0, 1: 2.0}),
    lambda: StaticTenantCache(2000, {0: 1.0, 1: 2.0}),
    lambda: WeightedTenantCache(2000, {0: 1.0, 1: 2.0}),
], ids=["slru", "pinned", "shared", "static", "weighted"])
def test_invalidation_is_neither_hit_nor_miss(make):
    """The unified stats contract: ``remove``/``invalidate`` never touch
    the hit/miss counters — present key, absent key, any cache class.
    Only the *next lookup* of an invalidated key records (one miss)."""
    cache = make()
    present = (0, "list", 1)
    absent = (1, "list", 9)
    cache.put(present, 100)
    cache.get(present)
    cache.get(absent)
    stats = cache_stats(cache)
    assert cache.invalidate(absent) is False
    cache.remove(absent)
    assert cache_stats(cache) == stats
    # the predicate reflects presence (pinned: key is in the pinned set)
    assert cache.invalidate(present) is True
    cache.remove(present)                 # idempotent, still no stats
    assert cache_stats(cache) == stats
    cache.get(present)                    # the miss happens here, once
    assert cache_stats(cache) == (stats[0], stats[1] + 1)


def test_weighted_floor_never_breached_under_adversarial_pressure():
    """One tenant hammers; the victim's quota must never drop below its
    documented floor (min_frac x weighted fair share)."""
    cache = WeightedTenantCache(4000, {0: 1.0, 1: 1.0},
                                realloc_every=16, step_frac=0.2)
    rng = np.random.default_rng(0)
    for _ in range(3000):
        key = (0, "list", int(rng.integers(0, 64)))
        if not cache.get(key):
            cache.put(key, int(rng.integers(50, 300)))
        assert cache.parts[1].capacity >= cache.floors[1]
        check_partition_invariants(cache, 4000)
    assert cache.reallocations > 0
    # the idle tenant donated quota, but only down to (within one
    # reallocation step of) its floor
    assert cache.parts[1].capacity < 2000
    assert cache.parts[1].capacity - cache.floors[1] < cache.step_bytes


# ------------------------------------------------------ hypothesis mode --

if HAVE_HYPOTHESIS:
    op_strategy = st.tuples(
        st.sampled_from(OPS),
        st.sampled_from(KEYS),
        st.integers(min_value=1, max_value=400))

    @settings(max_examples=120, deadline=None)
    @given(st.integers(min_value=0, max_value=1500),
           st.lists(op_strategy, min_size=1, max_size=120))
    def test_hypothesis_slru_accounting(capacity, ops):
        apply_slru_ops(SLRUCache(capacity), ops)

    tenant_op_strategy = st.tuples(
        st.sampled_from(OPS),
        st.tuples(st.integers(0, 2), st.just("list"),
                  st.integers(0, 15)),
        st.integers(min_value=1, max_value=400))

    @settings(max_examples=80, deadline=None)
    @given(st.sampled_from([StaticTenantCache, WeightedTenantCache]),
           st.lists(tenant_op_strategy, min_size=1, max_size=120))
    def test_hypothesis_partition_quota(cls, ops):
        cache = cls(2000, {0: 1.0, 1: 2.0, 2: 0.5})
        for op, key, nbytes in ops:
            apply_tenant_op(cache, op, key, nbytes)
            check_partition_invariants(cache, 2000)

    shrink_strategy = st.lists(
        st.tuples(st.sampled_from(("put", "get")), st.sampled_from(KEYS),
                  st.integers(min_value=1, max_value=400)),
        min_size=1, max_size=80)

    @settings(max_examples=80, deadline=None)
    @given(st.integers(min_value=200, max_value=2000), shrink_strategy,
           st.integers(min_value=0, max_value=2000))
    def test_hypothesis_shrink_below_protected(capacity, ops, target):
        cache = SLRUCache(capacity)
        for op, key, nbytes in ops:
            cache.put(key, nbytes) if op == "put" else cache.get(key)
            cache.get(key)           # promote: pressure the protected seg
        cache.set_capacity(target)
        assert cache.used_bytes <= cache.capacity
        assert cache.protected_bytes <= cache.protected_cap
        check_slru_invariants(cache)
