"""repro.obs.explain + repro.obs.mrc: tail exemplars, windowed
attribution, alert forensics and online miss-ratio curves observe
without perturbing (golden bit-exactness), the compaction-storm tail is
attributed to a queue/storage stage with the concurrent compaction
named, and the SHARDS estimator tracks the exact Che-approximation
curve within its documented tolerance.  Plus the PR 9 satellites:
histogram running sums that telescope across snapshot ticks,
degenerate-span-tree hardening, the byte-identical Perfetto double
export, and the --tune-split CLI path end-to-end."""
import dataclasses
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.obs import (MetricsRegistry, MonitorConfig, Tracer,
                       chrome_trace, render_explain, write_chrome_trace)
from repro.obs.critical_path import (STAGES, extract_paths, path_shares,
                                     query_path)
from repro.obs.mrc import (MRCConfig, MRCProfiler, TenantMRC,
                           default_size_grid, mrc_miss_ratio)
from repro.obs.trace import Span
from repro.sim.arrivals import Poisson, Scenario

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")

HEDGED_CFG = FleetConfig(n_shards=4, replication=2, concurrency=16,
                         shard_concurrency=4, queue_depth=16,
                         hedge=True, hedge_percentile=75.0, seed=5)

#: the cfg most non-golden tests share: hedged + a real cache so the
#: MRC estimator sees an access stream
CACHED_CFG = dataclasses.replace(HEDGED_CFG, cache_bytes=64 * 1024,
                                 cache_policy="slru")


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4, seed=0))
    return data, queries, ci


@pytest.fixture(scope="module")
def explained(setup):
    """One plain and one fully-observed (traced+explained+MRC) run of
    the same cached hedged fleet, shared across the contract tests."""
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    plain = run_fleet(ci, queries, p, CACHED_CFG)
    tracer = Tracer()
    obs = run_fleet(ci, queries, p, CACHED_CFG, tracer=tracer,
                    explain=True, mrc=True)
    return plain, obs, tracer


def _ids_sha256(report) -> str:
    h = hashlib.sha256()
    for r in sorted(report.records, key=lambda r: r.qid):
        h.update(np.asarray(r.qid).tobytes())
        h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
    return h.hexdigest()


# ----------------------------------------------------- bit-exactness --

def test_explained_run_reproduces_golden(setup):
    """Acceptance: explain + MRC are pure observers — an explained,
    MRC-profiled run still reproduces the pre-refactor goldens bit for
    bit (the explain reservoir uses its own seeded PRNG and the SHARDS
    hash touches no RNG at all)."""
    _, queries, ci = setup
    golden = json.load(open(GOLDEN_PATH))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64, seed=0),
        four_shard=HEDGED_CFG)
    for name, cfg in configs.items():
        rep = run_fleet(ci, queries, p, cfg, tracer=Tracer(),
                        explain=True, mrc=True)
        g = golden[name]
        assert rep.wall_time_s == pytest.approx(g["wall_time_s"],
                                                rel=1e-9, abs=1e-12)
        assert rep.qps == pytest.approx(g["qps"], rel=1e-9)
        assert _ids_sha256(rep) == g["ids_sha256"]


def test_explained_summary_equals_plain_minus_obs_blocks(explained):
    """An explained+profiled report is the plain report plus exactly
    the ``explain`` and ``mrc`` keys — nothing else moves."""
    plain, obs, _ = explained
    s_plain, s_obs = plain.summary(), obs.summary()
    assert "explain" not in s_plain and "mrc" not in s_plain
    exp = s_obs.pop("explain")
    mrc = s_obs.pop("mrc")
    assert s_obs == s_plain
    assert exp["n_queries"] == s_plain["n_queries"]
    assert exp["clusters"] and exp["headline"]
    assert mrc["tenants"] and mrc["tenants"][0]["name"] == "fleet"


def test_explain_report_deterministic(setup, explained):
    """Same seed, same run → byte-identical explain and MRC blocks
    (reservoirs and sampling are deterministic by construction)."""
    _, queries, ci = setup
    _, obs, _ = explained
    rep2 = run_fleet(ci, queries, SearchParams(k=10, nprobe=16),
                     CACHED_CFG, tracer=Tracer(), explain=True, mrc=True)
    assert json.dumps(obs.explain, sort_keys=True) == \
        json.dumps(rep2.explain, sort_keys=True)
    assert json.dumps(obs.mrc, sort_keys=True) == \
        json.dumps(rep2.mrc, sort_keys=True)


def test_explain_requires_tracer(setup):
    _, queries, ci = setup
    with pytest.raises(ValueError, match="tracer"):
        run_fleet(ci, queries, SearchParams(k=10, nprobe=16),
                  CACHED_CFG, explain=True)


# ------------------------------------------------ windowed attribution --

def test_windowed_attrib_published_as_counter_tracks(explained):
    """Stage shares land in the metrics time series (and therefore the
    Perfetto counter tracks): every snapshot row carries
    ``attrib.<stage>.share`` gauges in [0, 1] plus the window's query
    count, and windows with queries have shares that sum to ~1."""
    _, obs, tracer = explained
    rows = [row for _, row in tracer.metrics.series]
    assert rows
    for row in rows:
        assert "attrib.window.queries" in row
        for name in STAGES:
            share = row[f"attrib.{name}.share"]
            assert 0.0 <= share <= 1.0 + 1e-9
    busy = [row for row in rows if row["attrib.window.queries"] > 0]
    assert busy, "no snapshot window saw a completed query"
    for row in busy:
        tot = sum(row[f"attrib.{name}.share"] for name in STAGES)
        assert tot == pytest.approx(1.0, abs=1e-6)
    # the export renders them as counter tracks
    doc = chrome_trace(tracer)
    counter_names = {ev["name"] for ev in doc["traceEvents"]
                     if ev["ph"] == "C"}
    assert any(n.startswith("attrib.") and n.endswith(".share")
               for n in counter_names)
    # MRC gauges ride the same ticker
    assert any(n.startswith("cache.mrc.") for n in counter_names)


# --------------------------------------------------------- forensics --

def test_alert_forensics_attached_to_fired_alerts(setup):
    """When a burn-rate rule fires with an explain collector attached,
    the alert entry carries its root-cause bundle (window shares, worst
    exemplars, counter deltas) — and alerts without explain do not."""
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=3)
    mk = lambda: Poisson(rate_qps=3000.0, n_total=8 * len(queries))
    rep = run_fleet(ci, queries, p, cfg, arrivals=mk(), slo_s=0.005,
                    tracer=Tracer(), monitor=MonitorConfig(),
                    explain=True)
    fired = rep.alerts["fired"]
    assert fired, "overload run fired no alerts"
    with_forensics = [a for a in fired if "forensics" in a]
    assert with_forensics
    f = with_forensics[0]["forensics"]
    assert set(f) == {"at", "window", "exemplars", "counter_deltas"}
    assert f["at"] == pytest.approx(with_forensics[0]["fired_t"],
                                    abs=1e-6)
    for ex in f["exemplars"]:
        assert ex["stage"] in STAGES and ex["sojourn_s"] > 0
    # without explain, alert payloads are unchanged (no forensics key)
    rep2 = run_fleet(ci, queries, p, cfg, arrivals=mk(), slo_s=0.005,
                     monitor=MonitorConfig())
    assert all("forensics" not in a for a in rep2.alerts["fired"])


# ------------------------------------------------- compaction storm --

def test_compaction_storm_tail_names_the_compaction(setup):
    """Acceptance: on a write-storm rw scenario the p99.9 cluster is
    attributed to a queue/storage stage and the report names the
    concurrent compaction event."""
    from repro.ingest import IngestConfig, make_mutable, synth_updates
    data, queries, _ = setup
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                     seed=0))
    p = SearchParams(k=10, nprobe=32)
    # wide admission window so the wait surfaces at the shards, narrow
    # per-shard concurrency so compaction contention shows up as queue
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=256,
                      shard_concurrency=2, queue_depth=128, seed=2)
    stream = synth_updates(data, rate_qps=3000.0, n_updates=600,
                           delete_frac=0.2, seed=5)
    arr = Scenario(kind="rw", n_arrivals=4 * len(queries))
    rep = run_fleet(make_mutable(ci), queries, p, cfg,
                    arrivals=arr.make_arrivals(len(queries),
                                               cfg.concurrency),
                    updates=stream,
                    ingest=IngestConfig(delta_cap_bytes=16 * 1024,
                                        recluster=False),
                    tracer=Tracer(), explain=True)
    exp = rep.explain
    top = exp["clusters"][0]
    assert top["stage"] in ("queue", "storage_fetch")
    assert any(ev.startswith("compaction:") for ev in top["events"])
    assert "compaction:" in exp["headline"]
    assert top["shard"] >= 0
    # the renderer carries the same diagnosis
    text = render_explain(exp)
    assert "compaction:" in text and top["stage"] in text


# -------------------------------------- degenerate trees (satellite) --

def _mk(sid, name, t0, t1, parent=None, attrs=None):
    sp = Span(sid, name, t0, parent=parent, attrs=attrs)
    sp.t1 = t1
    return sp


def test_query_path_degenerate_trees_stay_finite():
    """Zero-duration queries, jobless rounds, unclosed children and
    aborted roots never produce NaN/KeyError — shares stay finite."""
    # unclosed root (query aborted before finishing): skipped, not fatal
    root = Span(0, "query", 1.0, attrs=dict(qid=7))
    assert query_path(root, {0: []}) is None

    # zero-duration root: all-zero finite shares
    z = _mk(0, "query", 2.0, 2.0, attrs=dict(qid=1))
    qp = query_path(z, {0: [_mk(1, "round", 2.0, 2.0, parent=0)]})
    assert qp is not None and qp.sojourn == 0.0
    shares = path_shares(qp)
    assert all(v == 0.0 for v in shares.values())
    assert all(np.isfinite(v) for v in shares.values())

    # jobless round (every shard job lost to a fault): charged to other
    r = _mk(0, "query", 0.0, 1.0, attrs=dict(qid=2))
    kids = {0: [_mk(1, "round", 0.0, 1.0, parent=0)], 1: []}
    qp = query_path(r, kids)
    assert qp.stages["other"] == pytest.approx(1.0)
    assert qp.accounted == pytest.approx(qp.sojourn)

    # unclosed legs clamp to the job end, unclosed job drops to the
    # jobless path — still finite
    r = _mk(0, "query", 0.0, 1.0, attrs=dict(qid=3))
    job = _mk(2, "shard_job", 0.1, 0.9, parent=1,
              attrs=dict(shard=0))
    leg = Span(3, "storage_fetch", 0.2, parent=2)        # never closed
    kids = {0: [_mk(1, "round", 0.0, 1.0, parent=0)],
            1: [job], 2: [leg]}
    kids[0][0].t1 = 1.0
    qp = query_path(r, kids)
    assert qp is not None
    assert all(np.isfinite(v) for v in qp.stages.values())
    assert all(v >= 0.0 for v in qp.stages.values())
    assert qp.stages["storage_fetch"] == pytest.approx(0.7)  # clamped

    # aborted mid-round: the round's only job never closed
    r = _mk(0, "query", 0.0, 0.5, attrs=dict(qid=4))
    open_job = Span(2, "shard_job", 0.1, parent=1, attrs=dict(shard=1))
    kids = {0: [_mk(1, "round", 0.0, 0.5, parent=0)], 1: [open_job]}
    qp = query_path(r, kids)
    assert qp.stages["other"] == pytest.approx(0.5)
    assert sum(path_shares(qp).values()) == pytest.approx(1.0)


def test_extract_paths_skips_malformed_roots():
    tr = Tracer()
    tr.spans.append(_mk(0, "query", 0.0, 1.0, attrs=dict(qid=0)))
    tr.spans.append(Span(1, "query", 0.5, attrs=dict(qid=1)))  # unclosed
    tr.spans.append(_mk(2, "compaction", 0.0, 2.0))            # not a query
    paths = extract_paths(tr)
    assert [p.qid for p in paths] == [0]
    assert paths[0].accounted == pytest.approx(paths[0].sojourn)


# ------------------------------------- histogram sums (satellite) --

def test_histogram_sum_and_snapshot_deltas_telescope():
    """Snapshot rows carry each histogram's running count/sum, so the
    delta between any two ticks reconstructs that window's mean without
    re-tracing, and the deltas telescope to the final totals."""
    m = MetricsRegistry()
    h = m.histogram("lat_s")
    windows = [(0.001, 0.002), (0.004,), (0.1, 0.2, 0.3)]
    for i, vals in enumerate(windows):
        for v in vals:
            h.observe(v)
        m.snapshot(float(i))
    d = h.to_dict()
    flat = [v for vals in windows for v in vals]
    assert d["count"] == len(flat)
    assert d["sum"] == pytest.approx(sum(flat))
    assert d["mean"] == pytest.approx(sum(flat) / len(flat))
    rows = [row for _, row in m.series]
    prev_c = prev_s = 0.0
    for vals, row in zip(windows, rows):
        dc = row["lat_s.count"] - prev_c
        ds = row["lat_s.sum"] - prev_s
        assert dc == len(vals)
        assert ds == pytest.approx(sum(vals))
        assert ds / dc == pytest.approx(np.mean(vals))   # windowed mean
        prev_c, prev_s = row["lat_s.count"], row["lat_s.sum"]
    # telescoped: last row equals the final histogram totals
    assert prev_c == d["count"]
    assert prev_s == pytest.approx(d["sum"])


# ------------------------------- Perfetto determinism (satellite) --

def test_perfetto_double_export_byte_identical(setup, tmp_path):
    """Two identical runs exported to disk produce byte-identical
    trace files (sorted counter tracks and lane metadata, pinned JSON
    separators)."""
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)

    def once(path):
        tr = Tracer()
        run_fleet(ci, queries, p, CACHED_CFG, tracer=tr,
                  explain=True, mrc=True)
        write_chrome_trace(path, tr)
        return tr

    tr = once(tmp_path / "a.json")
    once(tmp_path / "b.json")
    a = (tmp_path / "a.json").read_bytes()
    assert a == (tmp_path / "b.json").read_bytes()
    # re-exporting the same tracer is also stable
    write_chrome_trace(tmp_path / "a2.json", tr)
    assert a == (tmp_path / "a2.json").read_bytes()


# ----------------------------------------------------- SHARDS MRC --

def _zipf_stream(n_keys=200, n_accesses=20000, a=1.1, seed=0):
    rng = np.random.default_rng(seed)
    sizes = (rng.integers(1, 9, n_keys) * 64).astype(int)
    w = 1.0 / np.arange(1, n_keys + 1) ** a
    w /= w.sum()
    stream = rng.choice(n_keys, size=n_accesses, p=w)
    return sizes, stream


def test_shards_mrc_tracks_che_within_documented_tolerance():
    """Acceptance: on a synthetic zipf profile the SHARDS estimate
    stays within the tolerance documented in repro/obs/mrc.py —
    0.05 mean / 0.10 max abs miss-ratio error at sample_rate=1.0,
    0.08 / 0.15 at 0.25 — against the exact Che-approximation curve."""
    from repro.tuning.tenancy import che_hit_rate
    sizes, stream = _zipf_stream()
    profile = {("k", int(i)): [int(sizes[i]), int((stream == i).sum())]
               for i in np.unique(stream)}
    total = int(sizes.sum())
    grid = [total // 32, total // 16, total // 8, total // 4,
            total // 2, total]
    for rate, (tol_mean, tol_max) in ((1.0, (0.05, 0.10)),
                                      (0.25, (0.08, 0.15))):
        est = TenantMRC(rate)
        for i in stream:
            est.access(("k", int(i)), int(sizes[i]))
        errs = [abs(est.miss_ratio(c) - (1.0 - che_hit_rate(profile, c)))
                for c in grid]
        assert np.mean(errs) <= tol_mean, (rate, errs)
        assert np.max(errs) <= tol_max, (rate, errs)
        # curves are monotone non-increasing in cache size
        curve = est.curve(grid)
        assert all(a >= b - 1e-9 for a, b in zip(curve, curve[1:]))


def test_tenant_mrc_deterministic_and_bounded():
    sizes, stream = _zipf_stream(n_keys=100, n_accesses=5000)

    def run():
        est = TenantMRC(0.5)
        for i in stream:
            est.access(("k", int(i)), int(sizes[i]))
        return est
    a, b = run(), run()
    grid = default_size_grid(4096)
    assert a.to_dict(grid) == b.to_dict(grid)
    # ghost memory is bounded by the sampled key universe, not the
    # stream length
    assert len(a._stack) <= 100
    assert a.sampled < a.accesses == len(stream)


def test_mrc_profiler_observer_and_gauges():
    prof = MRCProfiler(MRCConfig(sample_rate=1.0), ref_bytes=512,
                       tenant_names={0: "hot", 1: "cold"})
    for _ in range(3):
        for tid in (0, 1):
            for k in range(4):
                prof.record_get((tid, "list", k), hit=False)
                prof.record_put((tid, "list", k), nbytes=128)
    assert sorted(prof._tenants) == [0, 1]
    reg = MetricsRegistry()
    prof.publish(reg)
    g = reg.to_dict()["gauges"]
    for name in ("hot", "cold"):
        assert f"cache.mrc.{name}.mr" in g
        assert f"cache.mrc.{name}.samples" in g
        assert 0.0 <= g[f"cache.mrc.{name}.mr"] <= 1.0
    d = prof.to_dict(wall_s=2.0)
    assert [t["name"] for t in d["tenants"]] == ["hot", "cold"]
    assert all(t["demand_bytes_per_s"] > 0 for t in d["tenants"])


def test_mrc_profiler_installs_on_cache_shapes():
    from repro.cache.slru import make_cache
    from repro.tenancy.policy import make_tenant_cache
    prof = MRCProfiler(MRCConfig(sample_rate=1.0), ref_bytes=1024)
    bare = make_cache("slru", 4096, ())
    prof.install(bare)
    bare.put((0, "list", 1), 100)
    bare.get((0, "list", 1))
    assert prof._tenants[0].accesses == 1
    shared = make_tenant_cache("shared", 4096, {0: 1.0, 1: 1.0})
    prof.install(shared)
    static = make_tenant_cache("static", 4096, {0: 0.5, 1: 0.5})
    prof.install(static)
    for part in static.parts.values():
        assert part.observer is prof
    prof.install(None)                      # silently skipped


def test_mrc_miss_ratio_interpolation_and_clamping():
    sizes = [1024, 4096, 16384]
    curve = [0.9, 0.5, 0.1]
    assert mrc_miss_ratio(sizes, curve, 10) == 0.9        # clamp low
    assert mrc_miss_ratio(sizes, curve, 10 ** 9) == 0.1   # clamp high
    mid = mrc_miss_ratio(sizes, curve, 2048)
    assert 0.5 < mid < 0.9
    assert mrc_miss_ratio(sizes, curve, 2048) == \
        pytest.approx(0.7)                 # log midpoint of 1024..4096
    with pytest.raises(ValueError):
        mrc_miss_ratio([], [], 100)


# ------------------------------------------------ tuner integration --

def _mrc_artifact(names, sizes, curves):
    return dict(sample_rate=1.0, ref_bytes=sizes[len(sizes) // 2],
                sizes=list(sizes),
                tenants=[dict(tid=i, name=n, accesses=1000,
                              sampled=1000, cold=10,
                              mean_obj_bytes=256.0,
                              sizes=list(sizes), miss_ratio=list(c),
                              demand_bytes_per_s=d)
                         for i, (n, c, d) in enumerate(
                             zip(names, curves, (4e6, 1e6)))])


def test_screen_cache_splits_accepts_mrc_curves():
    from repro.tenancy.fleet import materialize_tenant
    from repro.tenancy.spec import TenantSpec
    from repro.tuning.tenancy import screen_cache_splits
    specs = [TenantSpec(name="hot", n=500, dim=32, n_queries=8,
                        nprobe=8),
             TenantSpec(name="cold", n=500, dim=32, n_queries=8,
                        nprobe=8)]
    tenants = [materialize_tenant(s, base_seed=0, tid=i)
               for i, s in enumerate(specs)]
    sizes = [16 * 1024, 64 * 1024, 256 * 1024]
    # hot tenant's curve knees late (wants bytes), cold is flat
    art = _mrc_artifact(["hot", "cold"], sizes,
                        [[0.9, 0.6, 0.1], [0.3, 0.28, 0.27]])
    preds = screen_cache_splits(tenants, 256 * 1024, steps=4, mrc=art)
    assert preds[0].miss_bytes_per_s <= preds[-1].miss_bytes_per_s
    # the high-demand, kneed tenant gets the larger share
    assert preds[0].split.fractions[0] > preds[0].split.fractions[1]
    # unknown tenant names fail loudly
    bad = _mrc_artifact(["hot", "WRONG"], sizes,
                        [[0.9, 0.6, 0.1], [0.3, 0.28, 0.27]])
    with pytest.raises(ValueError, match="cold"):
        screen_cache_splits(tenants, 256 * 1024, steps=4, mrc=bad)


def test_live_mrc_feeds_tune_cache_split(setup):
    """End-to-end: profile a multi-tenant run online, hand the mrc
    block straight to the tuner."""
    from repro.tenancy.fleet import materialize_tenant, run_tenant_fleet
    from repro.tenancy.spec import TenantSpec
    from repro.tuning.tenancy import tune_cache_split
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8,
                      cache_bytes=96 * 1024, cache_policy="slru", seed=0)
    specs = [TenantSpec(name="hot", n=500, dim=32, n_queries=24,
                        nprobe=8),
             TenantSpec(name="cold", n=900, dim=32, n_queries=16,
                        nprobe=32)]
    tenants = [materialize_tenant(s, base_seed=0, tid=i)
               for i, s in enumerate(specs)]
    rep = run_tenant_fleet(tenants, cfg, "shared", mrc=True)
    mrc = rep.fleet.mrc
    assert {t["name"] for t in mrc["tenants"]} == {"hot", "cold"}
    rec = tune_cache_split(specs, cfg, steps=4, refine_top=1, mrc=mrc)
    assert abs(sum(rec.split.fractions) - 1.0) < 1e-9
    assert rec.outcomes


# ---------------------------------------------------------------- CLI --

def test_fleet_cli_explain_and_mrc_artifacts(tmp_path, capsys):
    from repro.fleet.__main__ import main
    epath, mpath = tmp_path / "explain.json", tmp_path / "mrc.json"
    rc = main(["--shards", "2", "--n", "600", "--queries", "16",
               "--cache-mb", "1", "--explain", str(epath),
               "--mrc", str(mpath), "--compact"])
    assert rc == 0
    captured = capsys.readouterr()
    out = json.loads(captured.out)
    assert "explain" in out["report"] and "mrc" in out["report"]
    assert "tail explanation" in captured.err
    exp = json.loads(epath.read_text())
    assert exp == out["report"]["explain"]
    mrc = json.loads(mpath.read_text())
    assert mrc == out["report"]["mrc"]
    assert out["report"]["explain"]["headline"]


def test_fleet_cli_without_flags_has_no_obs_blocks(capsys):
    from repro.fleet.__main__ import main
    rc = main(["--shards", "2", "--n", "600", "--queries", "16",
               "--cache-mb", "1", "--compact"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert "explain" not in out["report"] and "mrc" not in out["report"]


def test_tuning_cli_tune_split_with_mrc_curves(tmp_path, capsys):
    from repro.tuning.__main__ import main
    tenants = tmp_path / "tenants.json"
    tenants.write_text(json.dumps(dict(tenants=[
        dict(name="hot", n=500, dim=32, n_queries=8, nprobe=8),
        dict(name="cold", n=500, dim=32, n_queries=8, nprobe=8)])))
    sizes = [16 * 1024, 64 * 1024, 256 * 1024]
    art = tmp_path / "mrc.json"
    art.write_text(json.dumps(_mrc_artifact(
        ["hot", "cold"], sizes, [[0.9, 0.6, 0.1], [0.3, 0.28, 0.27]])))
    rc = main(["--tune-split", "--tenants", str(tenants),
               "--cache-gb", str(256 * 1024 / 2 ** 30),
               "--concurrency", "8", "--split-steps", "4",
               "--refine-top", "1", "--mrc-curves", str(art),
               "--compact"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert abs(sum(out["recommendation"]) - 1.0) < 1e-9
    assert out["screened"] and out["refined"]
    assert "meta" in out
