"""Tiered storage data path: DRAM -> local NVMe -> object store.

Four contracts from the tier PR's acceptance list:

* promotion is deterministic — same seed, same config, bit-identical
  fleet JSON and tier stats across replays (checked over 3 seeds);
* write-back makes compaction output visible at *local* completion,
  strictly before the async object-store flush lands;
* a device too small to hold anything degrades to the flat hierarchy —
  recall and results are unchanged, never an error;
* ``nvme_bytes=0`` constructs no tier at all and reproduces the
  pre-tier golden fleet report bit-exactly.
"""
import dataclasses
import hashlib
import json
import os
from collections import namedtuple

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.sim.kernel import Kernel
from repro.storage.simulator import StorageSim
from repro.storage.spec import NVME, TOS
from repro.storage.tier import (NVMeTier, TierConfig, TieredWritePath)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")

Rq = namedtuple("Rq", ["key", "nbytes"])


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


def _tier(capacity=1000, policy="second-hit", writeback=False, kernel=None):
    cfg = TierConfig(capacity_bytes=capacity, policy=policy,
                     writeback=writeback)
    return NVMeTier(cfg, kernel if kernel is not None else Kernel(seed=0))


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4, seed=0))
    return data, queries, gt, ci


def _ids_sha256(report) -> str:
    h = hashlib.sha256()
    for r in sorted(report.records, key=lambda r: r.qid):
        h.update(np.asarray(r.qid).tobytes())
        h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
    return h.hexdigest()


# ------------------------------------------------------------ unit: tier --

def test_tier_config_validation():
    with pytest.raises(ValueError):
        TierConfig(capacity_bytes=-1)
    with pytest.raises(ValueError):
        TierConfig(capacity_bytes=100, policy="always")  # not a policy
    with pytest.raises(AssertionError):
        NVMeTier(TierConfig(capacity_bytes=0), Kernel(seed=0))


def test_second_hit_promotes_only_on_repeat_miss():
    tier = _tier(policy="second-hit")
    (nv, rem) = tier.split([Rq("a", 100)])
    assert (nv, rem) == ([], [Rq("a", 100)])
    tier.note_remote_fetch("a", 100)       # first touch: ghost only
    assert "a" not in tier and tier.promotions == 0
    (nv, rem) = tier.split([Rq("a", 100)])
    assert rem == [Rq("a", 100)]           # still remote
    tier.note_remote_fetch("a", 100)       # second touch: admitted
    assert "a" in tier and tier.promotions == 1
    (nv, rem) = tier.split([Rq("a", 100)])
    assert nv == [Rq("a", 100)] and rem == []
    assert tier.hits == 1 and tier.nvme_bytes == 100


def test_admit_always_promotes_first_touch():
    tier = _tier(policy="admit-always")
    tier.note_remote_fetch("a", 100)
    assert "a" in tier and tier.promotions == 1


def test_ghost_list_is_byte_bounded():
    tier = _tier(capacity=300, policy="second-hit")
    for i in range(10):
        tier.note_remote_fetch(("k", i), 100)   # 10 ghosts, cap 300
    assert tier._ghost_bytes <= 300
    # the oldest ghosts aged out: re-touching them starts over
    tier.note_remote_fetch(("k", 0), 100)
    assert ("k", 0) not in tier


def test_residency_lru_eviction_order():
    tier = _tier(capacity=300, policy="admit-always")
    for i in range(3):
        tier.note_remote_fetch(("k", i), 100)
    tier.split([Rq(("k", 0), 100)])             # touch k0: now MRU
    tier.note_remote_fetch(("k", 3), 100)       # evicts LRU = k1
    assert ("k", 1) not in tier and ("k", 0) in tier
    assert tier.evictions == 1 and tier.used_bytes == 300


def test_admit_writeback_full_device_degrades_to_write_through():
    tier = _tier(capacity=300, writeback=True)
    assert tier.admit_writeback("big", 301) is False
    assert tier.writeback_fallbacks == 1 and "big" not in tier
    assert tier.admit_writeback("a", 200) is True
    assert tier.admit_writeback("a", 250) is True    # resize in place
    assert tier.used_bytes == 250 and tier.resident_keys == 1


def test_tier_invalidate_is_neither_hit_nor_miss():
    tier = _tier(policy="admit-always")
    tier.note_remote_fetch("a", 100)
    tier.split([Rq("a", 100), Rq("b", 50)])
    stats = (tier.hits, tier.misses)
    assert tier.invalidate("a") is True
    assert tier.invalidate("a") is False      # already gone
    assert tier.invalidate("zzz") is False
    assert (tier.hits, tier.misses) == stats
    assert tier.used_bytes == 0


def test_reset_clears_residency_but_keeps_cumulative_counters():
    tier = _tier(policy="admit-always")
    tier.note_remote_fetch("a", 100)
    tier.split([Rq("a", 100)])
    tier.reset()
    assert tier.resident_keys == 0 and tier.used_bytes == 0
    assert tier.hits == 1 and tier.promotions == 1   # billing survives
    assert "a" not in tier


# ------------------------------------------------- unit: write-back path --

def test_writeback_put_visible_before_flush_completes():
    """on_done (the install) fires at NVMe-local completion; the
    object-store flush lands strictly later."""
    kernel = Kernel(seed=0)
    remote = StorageSim(_quiet(TOS), kernel, seed=0)
    tier = NVMeTier(TierConfig(capacity_bytes=1 << 20, writeback=True,
                               spec=_quiet(NVME)), kernel, seed=1)
    wp = TieredWritePath(tier, remote)
    times = {}
    wp.submit_batch(100_000, 1, put=True,
                    on_done=lambda tk: times.setdefault("local",
                                                        kernel.now))
    kernel.run()
    assert wp.flushes_done == 1 and wp.flush_pending == 0
    # local visibility strictly precedes the remote flush: the device's
    # ~100us TTFB vs the object store's ~13ms
    assert times["local"] < kernel.now
    assert tier.sim.total_put_requests == 1
    assert remote.total_put_requests == 1     # the bill is deferred, not
    assert remote.total_put_bytes == 100_000  # avoided


def test_write_through_and_reads_bypass_the_device():
    kernel = Kernel(seed=0)
    remote = StorageSim(_quiet(TOS), kernel, seed=0)
    tier = NVMeTier(TierConfig(capacity_bytes=1 << 20, writeback=False,
                               spec=_quiet(NVME)), kernel, seed=1)
    wp = TieredWritePath(tier, remote)
    wp.submit_batch(50_000, 1, put=True)      # write-through PUT
    wp.submit_batch(50_000, 2, put=False)     # compaction re-read
    kernel.run()
    assert tier.sim.total_requests == 0
    assert remote.total_requests == 3
    assert wp.flushes_done == 0


# ----------------------------------------------------------- fleet level --

def test_promotion_determinism_across_seeds(setup):
    """Same seed => bit-identical fleet JSON and tier stats; promotions
    actually happen (the tier is live, not decorative)."""
    _, queries, _, ci = setup
    p = SearchParams(k=10, nprobe=32)
    for seed in (0, 1, 2):
        cfg = FleetConfig(n_shards=2, replication=1, storage=TOS,
                          concurrency=12, shard_concurrency=4,
                          queue_depth=32, nvme_bytes=4 << 20,
                          tier_policy="second-hit", seed=seed)
        a = run_fleet(ci, queries, p, cfg)
        b = run_fleet(ci, queries, p, cfg)
        assert a.to_json() == b.to_json()
        nv = [s.nvme for s in a.shard_stats]
        assert nv == [s.nvme for s in b.shard_stats]
        assert all(s is not None for s in nv)
        assert sum(s["promotions"] for s in nv) > 0
        assert sum(s["hits"] for s in nv) > 0


def test_full_device_fallback_keeps_results_exact(setup):
    """A device smaller than any non-empty object can only ever hold
    zero-byte residents: every real fetch falls through to remote and
    results/recall match the flat hierarchy exactly."""
    _, queries, gt, ci = setup
    p = SearchParams(k=10, nprobe=32)
    base = dict(n_shards=2, replication=1, storage=TOS, concurrency=12,
                shard_concurrency=4, queue_depth=32, seed=3)
    flat = run_fleet(ci, queries, p, FleetConfig(**base))
    tiny = run_fleet(ci, queries, p, FleetConfig(
        nvme_bytes=64, tier_policy="admit-always", **base))
    assert _ids_sha256(tiny) == _ids_sha256(flat)
    assert tiny.recall_against(gt) == flat.recall_against(gt)
    nv = [s.nvme for s in tiny.shard_stats]
    # nothing with payload ever landed on (or was served from) the device
    assert sum(s["promoted_bytes"] for s in nv) == 0
    assert sum(s["nvme_bytes"] for s in nv) == 0
    assert sum(s["used_bytes"] for s in nv) == 0
    assert sum(s["misses"] for s in nv) > 0


def test_nvme_zero_reproduces_pre_tier_golden(setup):
    """``--nvme-gb 0`` is the flat hierarchy: no second StorageSim is
    built, so the pre-tier golden reproduces bit-exactly."""
    _, queries, _, ci = setup
    golden = json.load(open(GOLDEN_PATH))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64,
                              nvme_bytes=0, seed=0),
        four_shard=FleetConfig(n_shards=4, replication=2, concurrency=16,
                               shard_concurrency=4, queue_depth=16,
                               hedge=True, hedge_percentile=75.0,
                               nvme_bytes=0, seed=5))
    for name, cfg in configs.items():
        rep = run_fleet(ci, queries, p, cfg)
        g = golden[name]
        assert rep.wall_time_s == pytest.approx(g["wall_time_s"],
                                                rel=1e-9, abs=1e-12)
        assert rep.qps == pytest.approx(g["qps"], rel=1e-9)
        assert _ids_sha256(rep) == g["ids_sha256"]
        assert all(s.nvme is None for s in rep.shard_stats)
        # off-default keys stay out of the config dict: old artifacts
        # round-trip unchanged
        assert "nvme_bytes" not in cfg.to_dict()
        assert "nvme" not in json.dumps(rep.summary())


def test_writeback_fleet_run_admits_and_flushes(setup):
    """Live ingest on a write-back tier: compaction output lands on the
    device (admits > 0), every flush reaches the object store, and
    results stay complete."""
    from repro.ingest import IngestConfig, synth_updates

    data, queries, _, ci = setup
    from repro.ingest import make_mutable
    p = SearchParams(k=10, nprobe=32)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8,
                      nvme_bytes=8 << 20, nvme_writeback=True, seed=2)
    stream = synth_updates(data, rate_qps=600.0, n_updates=120,
                           delete_frac=0.3, seed=3)
    rep = run_fleet(make_mutable(ci), queries, p, cfg, updates=stream,
                    ingest=IngestConfig(delta_cap_bytes=24 * 1024))
    assert len(rep.records) == rep.n_arrivals
    nv = [s.nvme for s in rep.shard_stats]
    assert all(s is not None for s in nv)
    assert sum(s["writeback_admits"] for s in nv) > 0
    assert sum(s["flushes_done"] for s in nv) > 0
    assert all(s["flush_pending"] == 0 for s in nv)   # run drained


# ------------------------------------------------------ budget tuning --

def test_enumerate_tier_splits_spends_the_budget():
    """Every enumerated split prices out to exactly the budget, each
    feasible width contributes both pure strategies (all-DRAM and
    all-NVMe), and an unpayable budget is a loud error."""
    from repro.obs.cost import PriceBook
    from repro.tuning import enumerate_tier_splits

    book = PriceBook()
    budget = 1.2
    splits = enumerate_tier_splits(budget, book, widths=(1, 2), steps=4)
    assert all(s.usd_per_hour(book) == pytest.approx(budget)
               for s in splits)
    for w in (1, 2):
        mine = [s for s in splits if s.n_shards == w]
        assert len(mine) == 5
        assert any(s.nvme_gib == 0 for s in mine)
        assert any(s.dram_gib == 0 for s in mine)
    # width 2 at $0.5/instance/h leaves nothing: only width 1 splits
    only_one = enumerate_tier_splits(0.8, book, widths=(1, 2), steps=2)
    assert {s.n_shards for s in only_one} == {1}
    with pytest.raises(ValueError, match="cannot pay"):
        enumerate_tier_splits(0.4, book, widths=(1,), steps=2)


def test_screen_tier_splits_orders_by_fetch_latency():
    """With a uniform profile much larger than any candidate, capacity
    wins: NVMe-heavy splits (more GiB per dollar) screen ahead of
    DRAM-heavy ones, and cumulative hit rates never invert."""
    from repro.obs.cost import PriceBook
    from repro.storage.spec import TOS
    from repro.tuning import enumerate_tier_splits, screen_tier_splits

    book = PriceBook()
    profile = {("list", i): [1 << 20, 1] for i in range(64 << 10)}  # 64 GiB
    splits = enumerate_tier_splits(1.2, book, widths=(1,), steps=4)
    preds = screen_tier_splits(profile, splits, book, remote_spec=TOS)
    assert [p.expected_fetch_s for p in preds] == \
        sorted(p.expected_fetch_s for p in preds)
    for p in preds:
        assert 0.0 <= p.hit_dram <= p.hit_nvme <= 1.0
        assert p.usd_per_hour == pytest.approx(1.2)
    by_nvme = max(preds, key=lambda p: p.split.nvme_gib)
    by_dram = max(preds, key=lambda p: p.split.dram_gib)
    assert by_nvme.expected_fetch_s < by_dram.expected_fetch_s


def test_tune_tier_split_end_to_end():
    """Screen + refine on a budget-starved workload: the refined runs
    measure real tier traffic and the pick spends the budget."""
    from repro.obs.cost import PriceBook
    from repro.tuning import EnvSpec, WorkloadSpec, tune_tier_split

    w = WorkloadSpec(n=8_000_000, dim=960, target_recall=0.5)
    env = EnvSpec(storage=TOS)
    rec = tune_tier_split(w, env, 0.56, widths=(1,), steps=4,
                          refine_top=2, eval_n=1200, nq=32, seed=0)
    assert rec.feasible
    assert len(rec.refined) == 2
    assert rec.split.usd_per_hour(PriceBook()) == pytest.approx(0.56)
    # the refined winner carried real tier traffic (device hits seen)
    picked = next(o for o in rec.refined if o.split == rec.split)
    if rec.split.nvme_gib > 0:
        assert picked.hit_nvme_frac > 0
    d = rec.to_dict()
    assert json.loads(rec.to_json()) == json.loads(json.dumps(d))
    assert d["recommendation"] == rec.split.to_dict()
    assert [p["expected_fetch_s"] for p in d["screened"]] == \
        sorted(p["expected_fetch_s"] for p in d["screened"])


def test_resolve_mrc_curve_shapes():
    """Bare curves pass through; a single-tenant --mrc artifact is
    unwrapped; multi-tenant artifacts are ambiguous and refuse."""
    from repro.tuning.tier import resolve_mrc_curve

    bare = {"sizes": [1, 2], "miss_ratio": [0.9, 0.1]}
    assert resolve_mrc_curve(bare) is bare
    row = {"name": "t0", "sizes": [1], "miss_ratio": [0.5]}
    assert resolve_mrc_curve({"tenants": [row]}) == row
    with pytest.raises(ValueError, match="one fleet-wide"):
        resolve_mrc_curve({"tenants": [row, dict(row, name="t1")]})
    with pytest.raises(ValueError, match="one fleet-wide"):
        resolve_mrc_curve({})
