"""Integration tests: serving engine reproduces the paper's mechanisms."""
import dataclasses

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex
from repro.core.types import ClusterIndexParams, GraphIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.serving.engine import EngineConfig, QueryEngine, run_workload
from repro.storage.spec import SSD, TOS, StorageSpec


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 2000, 32)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    ci = ClusterIndex.build(data, ClusterIndexParams(seed=0))
    gi = GraphIndex.build(data, GraphIndexParams(
        R=32, L_build=64, pq_dims=48, seed=0), batch=256)
    return data, queries, gt, ci, gi


def test_results_identical_to_direct_search(setup):
    """The engine changes *timing*, never *results*."""
    _, queries, _, ci, gi = setup
    p = SearchParams(k=10, nprobe=16)
    rep = run_workload(ci, queries[:8], p, _quiet(TOS))
    for rec in rep.records:
        direct = ci.search(queries[rec.qid], p)
        np.testing.assert_array_equal(rec.ids, direct.ids)
    p = SearchParams(k=10, search_len=40, beamwidth=8)
    rep = run_workload(gi, queries[:8], p, _quiet(TOS))
    for rec in rep.records:
        direct = gi.search(queries[rec.qid], p)
        np.testing.assert_array_equal(rec.ids, direct.ids)


def test_cloud_slower_than_ssd(setup):
    """Fig 3f: both indexes lose QPS moving disk -> remote storage."""
    _, queries, _, ci, gi = setup
    p = SearchParams(k=10, nprobe=32)
    qps = {}
    for spec in [TOS, SSD]:
        rep = run_workload(ci, queries, p, _quiet(spec))
        qps[spec.name] = rep.qps
    assert qps["local-ssd"] > 3 * qps["volcano-tos"]


def test_graph_latency_floor_is_rt_times_ttfb(setup):
    """§2.3.2: graph query latency >= roundtrips x TTFB on remote storage."""
    _, queries, _, _, gi = setup
    p = SearchParams(k=10, search_len=40, beamwidth=4)
    rep = run_workload(gi, queries[:10], p, _quiet(TOS))
    for rec in rep.records:
        floor = rec.metrics.roundtrips * TOS.ttfb_p50_s
        assert rec.latency >= 0.95 * floor


def test_concurrency_scales_graph_qps(setup):
    """Fig 3g: graph QPS scales with concurrency (I/O underutilised)."""
    _, queries, _, _, gi = setup
    p = SearchParams(k=10, search_len=40, beamwidth=8)
    q1 = run_workload(gi, queries, p, _quiet(TOS), concurrency=1).qps
    q16 = run_workload(gi, queries, p, _quiet(TOS), concurrency=16).qps
    assert q16 > 5 * q1


def test_cluster_congestion_at_high_concurrency(setup):
    """Fig 9: SPANN mean I/O latency rises with concurrency (shared bw)."""
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=128)
    io1 = run_workload(ci, queries, p, _quiet(TOS), concurrency=1)
    io32 = run_workload(ci, queries, p, _quiet(TOS), concurrency=32)
    assert io32.mean_io_latency > 2 * io1.mean_io_latency


def test_cache_reduces_storage_traffic(setup):
    """Fig 22: cache hits cut bytes-from-storage and requests (IOPS)."""
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=64)
    cold = run_workload(ci, np.concatenate([queries, queries]), p,
                        _quiet(TOS), cache_bytes=0)
    warm = run_workload(ci, np.concatenate([queries, queries]), p,
                        _quiet(TOS), cache_bytes=1 << 30)
    assert warm.hit_rate > 0.3
    assert warm.storage_bytes < cold.storage_bytes
    assert warm.storage_requests < cold.storage_requests
    assert warm.qps > cold.qps


def test_closed_loop_concurrency_bound(setup):
    """Never more than `concurrency` queries overlap in virtual time."""
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=16)
    rep = run_workload(ci, queries, p, _quiet(TOS), concurrency=4)
    events = []
    for r in rep.records:
        events.append((r.start_t, 1))
        events.append((r.end_t, -1))
    events.sort()
    live = peak = 0
    for _, delta in events:
        live += delta
        peak = max(peak, live)
    assert peak <= 4
    assert len(rep.records) == len(queries)


def test_engine_config_validation():
    """Bad cache configurations fail loudly at construction, not deep
    inside cache assembly."""
    with pytest.raises(ValueError, match="unknown cache_policy"):
        EngineConfig(storage=TOS, cache_policy="lru")
    with pytest.raises(ValueError, match="pinned"):
        EngineConfig(storage=TOS, cache_policy="pinned")  # no keys
    with pytest.raises(ValueError, match="pinned_keys"):
        EngineConfig(storage=TOS, cache_policy="slru",
                     pinned_keys=frozenset({("list", 0)}))
    with pytest.raises(ValueError, match="cache_bytes"):
        EngineConfig(storage=TOS, cache_bytes=-1)
    with pytest.raises(ValueError, match="concurrency"):
        EngineConfig(storage=TOS, concurrency=0)
    # valid corners still construct
    EngineConfig(storage=TOS, cache_policy="pinned",
                 pinned_keys=frozenset())
    EngineConfig(storage=TOS, cache_policy="none")


def test_engine_deterministic(setup):
    _, queries, _, ci, _ = setup
    p = SearchParams(k=10, nprobe=16)
    a = run_workload(ci, queries[:16], p, TOS, concurrency=4, seed=3)
    b = run_workload(ci, queries[:16], p, TOS, concurrency=4, seed=3)
    assert a.wall_time_s == b.wall_time_s
    assert a.qps == b.qps
