"""Roofline machinery: HLO parsing, trip counts, analytic cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.configs.shapes import SHAPES
from repro.launch import roofline as rf


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_collective_parse_simple():
    # single-device: no collectives
    c = _compile(lambda x: x @ x.T, jax.ShapeDtypeStruct((64, 64),
                                                         jnp.float32))
    assert rf.collective_bytes(c.as_text()) == {}


def test_trip_count_scan():
    def f(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                            length=12)[0]
    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    mult = rf.computation_multipliers(c.as_text())
    assert max(mult.values()) >= 12      # body weighted by trip count


def test_shape_bytes():
    assert rf._shape_bytes("f32", "4,8") == 128
    assert rf._shape_bytes("bf16", "10") == 20
    assert rf._shape_bytes("s8", "") == 1


def test_result_bytes_map():
    txt = """
  %dot.1 = f32[64,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}
  ROOT %tuple.2 = (f32[8]{0}, bf16[4,4]{1,0}) tuple(%x, %y)
"""
    sizes = rf._result_bytes_map(txt)
    assert sizes["dot.1"] == 64 * 128 * 4
    assert sizes["tuple.2"] == 8 * 4 + 16 * 2


def test_analytic_flops_matches_6nd_for_dense():
    """Analytic total must be close to 6·N·D x (waste >= 1) for a dense
    train cell — sanity-anchors the formulas."""
    cfg = ARCHS["internlm2-20b"]
    shape = SHAPES["train_4k"]
    got = rf.analytic_flops(cfg, shape)
    model = rf.model_flops_for(cfg, shape)
    assert model < got < 3.0 * model     # remat+attention waste bounded


def test_analytic_flops_moe_uses_active():
    cfg = ARCHS["dbrx-132b"]
    shape = SHAPES["train_4k"]
    got = rf.analytic_flops(cfg, shape)
    dense_equiv = 6.0 * (cfg.n_params() - cfg.vocab * cfg.d_model) \
        * shape.tokens
    assert got < 0.7 * dense_equiv       # sparse compute << dense


def test_decode_flops_tiny_vs_train():
    cfg = ARCHS["gemma-2b"]
    tr = rf.analytic_flops(cfg, SHAPES["train_4k"])
    de = rf.analytic_flops(cfg, SHAPES["decode_32k"])
    assert de < tr / 100


def test_roofline_terms_positive_and_bottleneck():
    r = rf.Roofline(chips=256, flops_per_device=1e12,
                    bytes_per_device=1e9, coll_bytes_per_device=1e8,
                    coll_breakdown={}, model_flops=2e14)
    rep = r.report()
    assert rep["bottleneck"] == "compute"
    assert 0 < rep["roofline_mfu"] <= 1.0


def test_model_flops_excludes_embedding_gather():
    cfg = ARCHS["gemma-2b"]        # 256k vocab, tied
    shape = SHAPES["train_4k"]
    n_mat = cfg.n_active_params() - cfg.vocab * cfg.d_model
    assert rf.model_flops_for(cfg, shape) == pytest.approx(
        6.0 * n_mat * shape.tokens)
