"""Scenario serving on the event kernel: equivalence with the
pre-refactor reports, open-loop arrivals, fault injection, autoscaling."""
import dataclasses
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.serving.engine import run_workload
from repro.sim.arrivals import Poisson, Scenario, zipf_trace
from repro.sim.autoscale import AutoscaleConfig
from repro.sim.faults import FaultSchedule, ShardFault
from repro.storage.spec import TOS
from repro.tuning import (EnvSpec, WorkloadSpec, resolve_storage,
                          tune_fleet_for_load)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4, seed=0))
    return data, queries, gt, ci


# ------------------------------------------------------ golden equivalence --

def _ids_sha256(report) -> str:
    h = hashlib.sha256()
    for r in sorted(report.records, key=lambda r: r.qid):
        h.update(np.asarray(r.qid).tobytes())
        h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
    return h.hexdigest()


def test_kernel_fleet_reproduces_prerefactor_reports(setup):
    """Acceptance: under closed-loop arrivals the kernel-based fleet
    reproduces the pre-refactor FleetReport — virtual time within 1e-9
    relative (exact in practice) and identical per-query results — for
    both the 1-shard config and a 4-shard replicated+hedged config.

    The golden file was captured from the pre-kernel implementation
    (four hand-rolled clock loops) immediately before the refactor.
    """
    _, queries, _, ci = setup
    golden = json.load(open(GOLDEN_PATH))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64, seed=0),
        four_shard=FleetConfig(n_shards=4, replication=2, concurrency=16,
                               shard_concurrency=4, queue_depth=16,
                               hedge=True, hedge_percentile=75.0, seed=5))
    for name, cfg in configs.items():
        rep = run_fleet(ci, queries, p, cfg)
        g = golden[name]
        assert rep.wall_time_s == pytest.approx(g["wall_time_s"],
                                                rel=1e-9, abs=1e-12)
        assert rep.qps == pytest.approx(g["qps"], rel=1e-9)
        assert _ids_sha256(rep) == g["ids_sha256"]   # recall identical


def test_one_shard_closed_loop_matches_query_engine(setup):
    """The fleet and the single engine share one kernel architecture:
    a 1-shard closed-loop fleet equals the QueryEngine report."""
    _, queries, _, ci = setup
    p = SearchParams(k=10, nprobe=16)
    mono = run_workload(ci, queries, p, _quiet(TOS), concurrency=8,
                        cache_policy="none")
    fleet = run_fleet(ci, queries, p, FleetConfig(
        n_shards=1, replication=1, storage=_quiet(TOS), concurrency=8,
        shard_concurrency=8, queue_depth=64))
    by_qid = {r.qid: r for r in mono.records}
    for rec in fleet.records:
        np.testing.assert_array_equal(rec.ids, by_qid[rec.qid].ids)
    assert fleet.qps == pytest.approx(mono.qps, rel=0.05)


# ------------------------------------------------------------- open loop --

def test_poisson_at_saturation_matches_closed_loop_throughput(setup):
    """Acceptance (satellite): open-loop Poisson far above capacity
    saturates the same window, so achieved QPS reproduces the
    closed-loop WorkloadReport within tolerance — and the backlog shows
    up as sojourn >> service latency."""
    _, queries, _, ci = setup
    p = SearchParams(k=10, nprobe=16)
    closed = run_workload(ci, queries, p, _quiet(TOS), concurrency=8,
                          cache_policy="none", seed=0)
    open_rep = run_workload(
        ci, queries, p, _quiet(TOS), concurrency=8, cache_policy="none",
        seed=0,
        arrivals=Poisson(rate_qps=20 * closed.qps,
                         n_total=2 * len(queries)))
    assert open_rep.scenario == "poisson"
    assert open_rep.n_arrivals == 2 * len(queries)
    assert open_rep.qps == pytest.approx(closed.qps, rel=0.15)
    assert open_rep.offered_qps > 5 * open_rep.qps       # truly saturated
    # queueing delay dominates: p50 sojourn far above p50 service latency
    assert open_rep.sojourn_percentile(50) > \
        3 * open_rep.latency_percentile(50)


def test_underloaded_open_loop_tracks_offered_rate(setup):
    """Below capacity the fleet serves what arrives: achieved ~ offered,
    goodput ~ 1."""
    _, queries, _, ci = setup
    p = SearchParams(k=10, nprobe=16)
    rep = run_fleet(ci, queries, p, FleetConfig(
        n_shards=2, replication=2, storage=TOS, concurrency=16, seed=0),
        arrivals=Poisson(rate_qps=100.0, duration_s=1.0), slo_s=0.25)
    assert rep.scenario == "poisson"
    assert rep.n_arrivals == len(rep.records)        # everything completed
    assert rep.qps == pytest.approx(rep.offered_qps, rel=0.2)
    assert rep.goodput_frac > 0.95
    assert rep.series is not None
    assert sum(rep.series.arrived) == rep.n_arrivals
    assert sum(rep.series.completed) == len(rep.records)


def test_open_loop_fleet_deterministic(setup):
    """Identical seeds give bit-identical open-loop JSON, burst incl."""
    _, queries, _, ci = setup
    p = SearchParams(k=10, nprobe=16)
    scenario = Scenario(kind="burst", rate_qps=150.0, duration_s=0.8,
                        burst_factor=4.0, slo_s=0.05)
    cfg = FleetConfig(n_shards=2, replication=2, storage=TOS,
                      concurrency=16, seed=9)

    def run_once():
        arr = scenario.make_arrivals(len(queries), cfg.concurrency, seed=9)
        return run_fleet(ci, queries, p, cfg, arrivals=arr,
                         slo_s=scenario.slo_s).to_json()

    assert run_once() == run_once()


def test_trace_replay_serves_zipf_workload(setup):
    """Trace arrivals cycle the query set with zipf popularity; every
    arrival is served and hot repeats make a shard cache pay."""
    _, queries, gt, ci = setup
    p = SearchParams(k=10, nprobe=16)
    trace = zipf_trace(len(queries), rate_qps=300.0, n_total=150, seed=3)
    rep = run_fleet(ci, queries, p, FleetConfig(
        n_shards=2, replication=1, storage=_quiet(TOS), concurrency=16,
        cache_bytes=1 << 30, cache_policy="slru", seed=3),
        arrivals=trace)
    assert rep.scenario == "trace"
    assert len(rep.records) == 150
    assert rep.hit_rate > 0.2
    assert rep.recall_against(gt) == 1.0


# ----------------------------------------------------------------- faults --

def test_shard_failure_recovers_on_replicas_with_recall_unchanged(setup):
    """Acceptance: killing a shard mid-run degrades p99 sojourn but not
    recall when replication >= 2 — its jobs re-route to surviving
    replica owners and every arrival still completes."""
    _, queries, gt, ci = setup
    p = SearchParams(k=10, nprobe=64)
    base = dict(n_shards=4, replication=2, storage=TOS, concurrency=24,
                shard_concurrency=4, queue_depth=32, seed=2)
    # calibrate offered load to ~85% of the 4-shard closed-loop capacity
    cal = run_fleet(ci, queries, p, FleetConfig(**base))
    rate = 0.85 * cal.qps
    arr = lambda: Poisson(rate_qps=rate, n_total=6 * len(queries))
    slo = 0.1

    clean = run_fleet(ci, queries, p, FleetConfig(**base),
                      arrivals=arr(), slo_s=slo)
    horizon = clean.wall_time_s
    faults = FaultSchedule((ShardFault(
        shard=1, t_fail=0.2 * horizon, t_recover=0.7 * horizon),))
    faulty = run_fleet(ci, queries, p, FleetConfig(**base),
                       arrivals=arr(), faults=faults, slo_s=slo)

    assert faulty.fault_log is not None
    assert [e["event"] for e in faulty.fault_log] == ["fail", "recover"]
    # no query lost, results exact, recall identical to the clean run
    assert len(faulty.records) == faulty.n_arrivals == clean.n_arrivals
    assert all((r.ids >= 0).all() for r in faulty.records)
    assert faulty.recall_against(gt) == clean.recall_against(gt)
    # a quarter of capacity vanished under ~85% load: the tail degrades
    assert faulty.sojourn_percentile(99) > clean.sojourn_percentile(99)


def test_fault_during_hedged_run_keeps_results_complete(setup):
    """A fault that kills one sub-job of a multi-shard hedge attempt must
    not let the surviving hedge tags complete the slot with a partial
    key set: the wounded attempt is dropped wholesale and results stay
    exact."""
    _, queries, gt, ci = setup
    p = SearchParams(k=10, nprobe=64)
    heavy = dataclasses.replace(TOS, ttfb_sigma=1.1)   # hedges fire a lot
    base = dict(n_shards=4, replication=2, storage=heavy, concurrency=8,
                shard_concurrency=8, queue_depth=64, seed=3,
                hedge=True, hedge_percentile=70.0, hedge_min_samples=16)
    arr = lambda: Poisson(rate_qps=120.0, n_total=4 * len(queries))
    clean = run_fleet(ci, queries, p, FleetConfig(**base), arrivals=arr())
    assert clean.hedges_launched > 0
    faults = FaultSchedule(tuple(
        ShardFault(shard=s, t_fail=0.15 * clean.wall_time_s * (s + 1),
                   t_recover=0.15 * clean.wall_time_s * (s + 1) + 0.2)
        for s in range(4)))                            # rolling failures
    faulty = run_fleet(ci, queries, p, FleetConfig(**base),
                       arrivals=arr(), faults=faults)
    assert faulty.hedges_launched > 0
    assert len(faulty.records) == faulty.n_arrivals
    assert all((r.ids >= 0).all() for r in faulty.records)
    assert faulty.recall_against(gt) == clean.recall_against(gt)


def test_failure_without_replication_backs_off_until_recovery(setup):
    """R=1: the dead shard's keys are unroutable until it recovers, but
    recovery drains the backlog and nothing is dropped."""
    _, queries, gt, ci = setup
    p = SearchParams(k=10, nprobe=32)
    faults = FaultSchedule((ShardFault(shard=0, t_fail=0.05,
                                       t_recover=0.35),))
    rep = run_fleet(ci, queries, p, FleetConfig(
        n_shards=2, replication=1, storage=TOS, concurrency=8, seed=4),
        arrivals=Poisson(rate_qps=150.0, duration_s=0.5), faults=faults)
    assert len(rep.records) == rep.n_arrivals
    assert rep.recall_against(gt) == 1.0
    assert sum(r.shed_retries for r in rep.records) > 0   # backed off


def test_fault_spec_parsing_and_validation():
    f = ShardFault.parse("2:0.5:1.5")
    assert (f.shard, f.t_fail, f.t_recover) == (2, 0.5, 1.5)
    assert ShardFault.parse("0:1.0").t_recover is None
    with pytest.raises(ValueError):
        ShardFault.parse("nope")
    with pytest.raises(ValueError):
        ShardFault(shard=0, t_fail=1.0, t_recover=0.5)
    sched = FaultSchedule.parse(["0:0.1:0.2", "1:0.3"])
    assert len(sched.faults) == 2


# -------------------------------------------------------------- autoscale --

def test_autoscaler_defends_slo_and_reports_cost(setup):
    """Under a saturating open-loop load the SLO controller adds shard
    instances (shards·seconds cost rises vs the fixed fleet) and lifts
    goodput."""
    _, queries, _, ci = setup
    p = SearchParams(k=10, nprobe=64)
    base = dict(n_shards=2, replication=1, storage=TOS, concurrency=32,
                shard_concurrency=4, queue_depth=32, seed=6)
    cal = run_fleet(ci, queries, p, FleetConfig(**base))
    rate = 1.6 * cal.qps                       # well beyond fixed capacity
    slo = 0.08
    arr = lambda: Poisson(rate_qps=rate, n_total=5 * len(queries))

    fixed = run_fleet(ci, queries, p, FleetConfig(**base),
                      arrivals=arr(), slo_s=slo)
    scaled_rep = run_fleet(
        ci, queries, p, FleetConfig(**base), arrivals=arr(), slo_s=slo,
        autoscale=AutoscaleConfig(slo_p99_s=slo, check_interval_s=0.05,
                                  cooldown_s=0.1, max_instances=4))

    assert scaled_rep.scale_events is not None
    assert any(e["action"] == "up" for e in scaled_rep.scale_events)
    assert max(scaled_rep.series.instances) > 2
    assert scaled_rep.shards_seconds > 0
    # capacity added: faster drain and better goodput than the fixed fleet
    assert scaled_rep.goodput_frac > fixed.goodput_frac
    assert scaled_rep.wall_time_s < fixed.wall_time_s
    # cost is honest: more than the always-2-instances baseline would
    # bill over the same (shorter) wall, less than max_instances forever
    assert scaled_rep.shards_seconds > 2 * scaled_rep.wall_time_s
    assert scaled_rep.shards_seconds < 8 * scaled_rep.wall_time_s


def test_autoscale_config_validation():
    with pytest.raises(ValueError):
        AutoscaleConfig(slo_p99_s=0.0)
    with pytest.raises(ValueError):
        AutoscaleConfig(slo_p99_s=0.1, down_error=0.5, up_error=0.0)
    with pytest.raises(ValueError):
        AutoscaleConfig(slo_p99_s=0.1, min_instances=3, max_instances=2)


# ------------------------------------------------------- tuning scenario --

def test_tune_fleet_for_load_picks_bigger_fleet_for_harder_slo():
    w = WorkloadSpec(n=1_000_000, dim=96, target_recall=0.9,
                     concurrency=16)
    env = EnvSpec(storage=resolve_storage("tos"))
    mk = lambda rate: Scenario(kind="poisson", rate_qps=rate,
                               duration_s=0.5, slo_s=0.06)
    easy = tune_fleet_for_load(w, env, mk(150.0), shard_grid=(1, 2, 4),
                               replica_grid=(1, 2), eval_n=800, nq=32)
    hard = tune_fleet_for_load(w, env, mk(900.0), shard_grid=(1, 2, 4),
                               replica_grid=(1, 2), eval_n=800, nq=32)
    assert easy.feasible
    e = easy.point.n_shards * easy.point.replication
    h = hard.point.n_shards * hard.point.replication
    assert h >= e
    with pytest.raises(ValueError, match="open-loop"):
        tune_fleet_for_load(w, env, Scenario(kind="closed"))
