"""Chunked (online-softmax) attention must equal the materialising path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa, _sdpa_chunked, causal_mask


def _mk(B, S, H, KV, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (4, 1)])
def test_chunked_matches_full(H, KV):
    B, S, hd = 2, 256, 16
    q, k, v = _mk(B, S, H, KV, hd)
    full = _sdpa(q, k, v, causal_mask(S, S), KV)
    chunked = _sdpa_chunked(q, k, v, KV, chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_chunked_local_window_matches_full(window):
    B, S, H, KV, hd = 1, 256, 4, 2, 16
    q, k, v = _mk(B, S, H, KV, hd, seed=1)
    full = _sdpa(q, k, v, causal_mask(S, S, window), KV)
    chunked = _sdpa_chunked(q, k, v, KV, window=window, chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_chunked_grads_finite():
    B, S, H, KV, hd = 1, 128, 4, 2, 8
    q, k, v = _mk(B, S, H, KV, hd, seed=2)

    def f(q, k, v):
        return _sdpa_chunked(q, k, v, KV, chunk=32).sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()


def test_single_chunk_degenerate():
    B, S, H, KV, hd = 2, 64, 4, 4, 16
    q, k, v = _mk(B, S, H, KV, hd, seed=3)
    full = _sdpa(q, k, v, causal_mask(S, S), KV)
    one = _sdpa_chunked(q, k, v, KV, chunk=64)
    np.testing.assert_allclose(np.asarray(one), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
