"""Shared test configuration.

Per-test timeout: an event-kernel scheduling bug would present as a test
that never finishes; rather than stalling CI for the job-level timeout,
every test gets a SIGALRM watchdog (default 120s, override with
REPRO_TEST_TIMEOUT_S; 0 disables).  POSIX main-thread only — elsewhere
the fixture is a no-op.
"""
import os
import signal
import threading

import pytest

_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "120"))


@pytest.fixture(autouse=True)
def _per_test_timeout(request):
    if (_TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM")
            or threading.current_thread() is not threading.main_thread()):
        yield
        return

    def _alarm(signum, frame):
        raise TimeoutError(
            f"{request.node.nodeid} exceeded {_TIMEOUT_S}s "
            f"(REPRO_TEST_TIMEOUT_S) — suspected event-loop hang")

    old_handler = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old_handler)
