import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex, device_search_batch
from repro.core.flat import exact_topk
from repro.core.types import ClusterIndexParams, SearchParams, recall_at_k
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled


@pytest.fixture(scope="module")
def built():
    spec = scaled(DEEP_ANALOG, 2000, 20)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    idx = ClusterIndex.build(
        data, ClusterIndexParams(centroid_frac=0.16, num_replica=8, seed=0))
    return data, queries, gt, idx


def _mean_recall(idx, queries, gt, nprobe):
    recs = []
    for i, q in enumerate(queries):
        r = idx.search(q, SearchParams(k=10, nprobe=nprobe))
        recs.append(recall_at_k(r.ids, gt[i]))
    return float(np.mean(recs))


def test_recall_monotonic_in_nprobe(built):
    _, queries, gt, idx = built
    r8 = _mean_recall(idx, queries, gt, 8)
    r64 = _mean_recall(idx, queries, gt, 64)
    rmax = _mean_recall(idx, queries, gt, idx.meta.n_lists)
    assert r8 <= r64 + 0.05
    assert r64 <= rmax + 0.02
    assert rmax >= 0.99          # probing everything must be ~exact
    assert r64 >= 0.8


def test_no_duplicate_results(built):
    _, queries, gt, idx = built
    r = idx.search(queries[0], SearchParams(k=10, nprobe=32))
    valid = r.ids[r.ids >= 0]
    assert len(np.unique(valid)) == len(valid)


def test_metrics_consistency(built):
    _, queries, _, idx = built
    r = idx.search(queries[0], SearchParams(k=10, nprobe=16))
    m = r.metrics
    assert m.roundtrips == 1                   # dependency-free fetch
    assert m.requests == m.lists_visited == 16
    assert m.dist_comps > 0


def test_replication_increases_index_size():
    spec = scaled(DEEP_ANALOG, 1500, 10)
    data, _ = make_dataset(spec)
    i2 = ClusterIndex.build(data, ClusterIndexParams(num_replica=2, seed=0))
    i8 = ClusterIndex.build(data, ClusterIndexParams(num_replica=8, seed=0))
    assert i8.meta.index_bytes > i2.meta.index_bytes
    # paper Table 4: replication inflates size by <= ~3x vs 1-replica IVF
    assert i8.meta.index_bytes < 4 * i2.meta.index_bytes


def test_centroid_frac_controls_list_size():
    spec = scaled(DEEP_ANALOG, 1500, 10)
    data, _ = make_dataset(spec)
    i16 = ClusterIndex.build(
        data, ClusterIndexParams(centroid_frac=0.08, seed=0))
    i32 = ClusterIndex.build(
        data, ClusterIndexParams(centroid_frac=0.32, seed=0))
    assert i32.meta.n_lists > i16.meta.n_lists
    assert i32.meta.avg_list_bytes < i16.meta.avg_list_bytes


def test_device_search_matches_host(built):
    data, queries, gt, idx = built
    arrs = idx.device_arrays()
    ids, dists = device_search_batch(
        jnp.asarray(arrs["centroids"]), jnp.asarray(arrs["list_vecs"]),
        jnp.asarray(arrs["list_ids"]), jnp.asarray(queries, jnp.float32)[:8],
        nprobe=32, k=10)
    ids = np.asarray(ids)
    for i in range(8):
        host = idx.search(queries[i], SearchParams(k=10, nprobe=32))
        # same top-k set modulo centroid-selection (BKT vs flat) differences
        overlap = len(np.intersect1d(ids[i], host.ids)) / 10
        assert overlap >= 0.7, (i, ids[i], host.ids)
        assert recall_at_k(ids[i], gt[i]) >= 0.7


def test_int8_dataset_build_and_search():
    from repro.data.synth import MSSPACE_ANALOG
    spec = scaled(MSSPACE_ANALOG, 1500, 10)
    data, queries = make_dataset(spec)
    assert data.dtype == np.int8
    gt, _ = exact_topk(data, queries, 10)
    idx = ClusterIndex.build(data, ClusterIndexParams(seed=0))
    r = _mean_recall(idx, queries, gt, 64)
    assert r >= 0.8
    # int8 posting lists are ~4x smaller than f32 would be
    assert idx.meta.avg_list_bytes < idx.meta.list_lengths.mean() * (
        spec.dim * 4 + 8)
