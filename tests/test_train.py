"""Training substrate: optimizer, train loop, checkpointing, pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, smoke
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import LM
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.runner import RunnerConfig, run
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = smoke(ARCHS["gemma-2b"])
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    ocfg = opt.OptimizerConfig(peak_lr=3e-3, warmup_steps=5,
                               total_steps=200)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=8, seed=0))
    return lm, params, ocfg, pipe


def test_loss_decreases(setup):
    lm, params, ocfg, pipe = setup
    step_fn = jax.jit(make_train_step(lm, ocfg))
    state = opt.init_state(params)
    losses = []
    for s in range(30):
        batch = jax.tree.map(jnp.asarray, pipe.batch(s))
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatch_equivalence(setup):
    lm, params, ocfg, pipe = setup
    batch = jax.tree.map(jnp.asarray, pipe.batch(0))
    s1 = opt.init_state(params)
    s2 = opt.init_state(params)
    p1, _, m1 = jax.jit(make_train_step(lm, ocfg, microbatches=1))(
        params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(lm, ocfg, microbatches=4))(
        params, s2, batch)
    # grads averaged over microbatches ~= full-batch grads
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_schedule_shape():
    ocfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=10,
                               total_steps=100)
    lrs = [float(opt.schedule(ocfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-12
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path, setup):
    lm, params, ocfg, _ = setup
    state = opt.init_state(params)
    tree = {"params": params, "opt": state}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored = ckpt.restore(str(tmp_path), 7,
                            jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_torn_write_invisible(tmp_path, setup):
    lm, params, *_ = setup
    ckpt.save(str(tmp_path), 1, {"p": params})
    # simulate a torn write: step dir without manifest
    torn = tmp_path / "step_0000000002"
    torn.mkdir()
    (torn / "junk.npy").write_bytes(b"xx")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_gc(tmp_path, setup):
    _, params, *_ = setup
    for s in [1, 2, 3, 4]:
        ckpt.save(str(tmp_path), s, {"p": params})
    ckpt.gc_old(str(tmp_path), keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert sorted(os.listdir(tmp_path))[-2:] == [
        "step_0000000003", "step_0000000004"]


def test_runner_resume(tmp_path, setup):
    lm, params, ocfg, pipe = setup
    step_fn = jax.jit(make_train_step(lm, ocfg))
    state = opt.init_state(params)
    nb = lambda s: jax.tree.map(jnp.asarray, pipe.batch(s))
    rcfg = RunnerConfig(total_steps=6, ckpt_dir=str(tmp_path),
                        ckpt_every=3, log_every=100)
    p1, s1, rep1 = run(rcfg, step_fn, params, state, nb,
                       log=lambda *_: None)
    assert rep1.final_step == 6
    # second run resumes from step 6's checkpoint... extend total
    rcfg2 = RunnerConfig(total_steps=9, ckpt_dir=str(tmp_path),
                         ckpt_every=3, log_every=100)
    p2, s2, rep2 = run(rcfg2, step_fn, params, state, nb,
                       log=lambda *_: None)
    assert rep2.steps_run == 3          # only the remaining steps
    assert int(s2["step"]) == 9


def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=3)
    p = TokenPipeline(cfg)
    a = p.batch(5)
    b = p.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # worker shards are disjoint streams covering the global batch
    w0 = p.batch(5, worker=0, n_workers=2)
    w1 = p.batch(5, worker=1, n_workers=2)
    assert w0["tokens"].shape[0] == 4
    assert not np.array_equal(w0["tokens"], w1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_pipeline_learnable_structure():
    """The synthetic language must carry signal (bigram structure)."""
    cfg = DataConfig(vocab=64, seq_len=64, global_batch=16, seed=0)
    p = TokenPipeline(cfg)
    b = p.batch(0)
    # successor entropy given token should be far below uniform
    pairs = {}
    for row in range(16):
        for t in range(63):
            key = int(b["tokens"][row, t])
            pairs.setdefault(key, []).append(int(b["tokens"][row, t + 1]))
    frac_top4 = []
    for key, succ in pairs.items():
        if len(succ) >= 8:
            vals, counts = np.unique(succ, return_counts=True)
            frac_top4.append(counts[np.argsort(-counts)][:4].sum()
                             / len(succ))
    assert np.mean(frac_top4) > 0.5
