import numpy as np
import pytest

from repro.core.flat import exact_topk
from repro.core.graph_index import GraphIndex, _merge_candidates, _robust_prune
from repro.core.types import GraphIndexParams, SearchParams, recall_at_k
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled


@pytest.fixture(scope="module")
def built():
    spec = scaled(DEEP_ANALOG, 2000, 20)
    data, queries = make_dataset(spec)
    gt, _ = exact_topk(data, queries, 10)
    idx = GraphIndex.build(
        data, GraphIndexParams(R=32, L_build=64, pq_dims=48, seed=0),
        batch=256)
    return data, queries, gt, idx


def _run(idx, queries, gt, **kw):
    recs, rts, reqs = [], [], []
    for i, q in enumerate(queries):
        r = idx.search(q, SearchParams(k=10, **kw))
        recs.append(recall_at_k(r.ids, gt[i]))
        rts.append(r.metrics.roundtrips)
        reqs.append(r.metrics.requests)
    return float(np.mean(recs)), float(np.mean(rts)), float(np.mean(reqs))


def test_recall_increases_with_search_len(built):
    _, queries, gt, idx = built
    r10, rt10, _ = _run(idx, queries, gt, search_len=10, beamwidth=8)
    r80, rt80, _ = _run(idx, queries, gt, search_len=80, beamwidth=8)
    assert r80 >= r10
    assert r80 >= 0.9
    assert rt80 > rt10          # paper: higher recall -> more roundtrips


def test_beamwidth_reduces_roundtrips(built):
    """Paper Fig 19a: higher W -> fewer roundtrips, more requests/query."""
    _, queries, gt, idx = built
    r1, rt1, req1 = _run(idx, queries, gt, search_len=80, beamwidth=1)
    r16, rt16, req16 = _run(idx, queries, gt, search_len=80, beamwidth=16)
    assert rt16 < rt1
    assert abs(r16 - r1) < 0.08  # recall roughly preserved


def test_graph_degree_bounded(built):
    data, _, _, idx = built
    arrs = idx.device_arrays()
    adj = arrs["adjacency"]
    assert adj.shape[1] == idx.meta.params.R
    valid = adj >= 0
    assert valid.sum(1).max() <= idx.meta.params.R
    # no self loops
    self_loop = adj == np.arange(len(adj))[:, None]
    assert not self_loop.any()


def test_exact_rerank_distances(built):
    data, queries, _, idx = built
    r = idx.search(queries[0], SearchParams(k=10, search_len=40, beamwidth=8))
    valid = r.ids >= 0
    want = ((data[r.ids[valid]].astype(np.float32)
             - queries[0].astype(np.float32)[None]) ** 2).sum(1)
    np.testing.assert_allclose(r.dists[valid], want, rtol=1e-4)


def test_node_block_is_sector_aligned(built):
    _, _, _, idx = built
    assert idx.meta.node_nbytes % idx.meta.params.sector_bytes == 0
    # 96-d f32 + 32 neighbours fits one 4KB sector
    assert idx.meta.node_nbytes == 4096


def test_denser_graph_bigger_blocks():
    spec = scaled(DEEP_ANALOG, 800, 5)
    data, _ = make_dataset(spec)
    # 96-d f32 vector (384B) + 1000*4B adjacency spills into a 2nd sector
    big = GraphIndex.build(
        data, GraphIndexParams(R=1000, L_build=32, build_passes=1, seed=0),
        batch=256)
    small = GraphIndex.build(
        data, GraphIndexParams(R=32, L_build=32, build_passes=1, seed=0),
        batch=256)
    assert big.meta.node_nbytes > small.meta.node_nbytes


# ---------------------------------------------------------------- units --

def test_merge_candidates_invariants():
    rng = np.random.default_rng(0)
    B, L = 4, 8
    cand_ids = rng.integers(0, 50, size=(B, L)).astype(np.int64)
    cand_d = rng.random((B, L)).astype(np.float32)
    expanded = rng.random((B, L)) < 0.5
    new_ids = rng.integers(0, 50, size=(B, 6)).astype(np.int64)
    new_d = rng.random((B, 6)).astype(np.float32)
    ids, d, e = _merge_candidates(cand_ids, cand_d, expanded,
                                  new_ids, new_d, L)
    assert ids.shape == (B, L)
    for b in range(B):
        valid = ids[b][ids[b] >= 0]
        assert len(np.unique(valid)) == len(valid)       # dedup
        dv = d[b][ids[b] >= 0]
        assert (np.diff(dv) >= -1e-6).all()              # sorted


def test_merge_keeps_expanded_flag():
    # the same id as both expanded-candidate and new neighbour must stay
    # expanded (otherwise traversal loops forever)
    cand_ids = np.array([[7, -1]], dtype=np.int64)
    cand_d = np.array([[1.0, np.inf]], dtype=np.float32)
    expanded = np.array([[True, False]])
    new_ids = np.array([[7, 3]], dtype=np.int64)
    new_d = np.array([[1.0, 2.0]], dtype=np.float32)
    ids, d, e = _merge_candidates(cand_ids, cand_d, expanded,
                                  new_ids, new_d, 2)
    assert ids[0, 0] == 7 and e[0, 0]
    assert ids[0, 1] == 3 and not e[0, 1]


def test_robust_prune_properties():
    rng = np.random.default_rng(0)
    p = rng.normal(size=16).astype(np.float32)
    cand = rng.normal(size=(64, 16)).astype(np.float32)
    ids = np.arange(100, 164, dtype=np.int64)
    sel = _robust_prune(p, ids, cand, R=8, alpha=1.2)
    assert len(sel) <= 8
    assert len(np.unique(sel)) == len(sel)
    d = ((cand - p) ** 2).sum(1)
    assert sel[0] == ids[np.argmin(d)]      # nearest always kept

    # alpha=inf keeps only nearest-first greedy wins; alpha=1.0 prunes most
    sel_tight = _robust_prune(p, ids, cand, R=8, alpha=1.0)
    assert len(sel_tight) <= len(sel)
