"""Property-based tests (hypothesis) on system-level invariants."""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost_model import (ClusterWorkloadPoint, GraphWorkloadPoint,
                                   cluster_query_cost, graph_query_cost,
                                   predicted_qps)
from repro.core.types import recall_at_k
from repro.launch import roofline as rf
from repro.storage.spec import SSD, TOS
from repro.storage.simulator import StorageSim


@settings(max_examples=40, deadline=None)
@given(nprobe=st.integers(1, 4096), conc=st.integers(1, 64))
def test_cluster_cost_monotone_in_nprobe_and_concurrency(nprobe, conc):
    w = lambda np_: ClusterWorkloadPoint(
        n_lists=10_000, avg_list_bytes=64_000, avg_list_len=40, dim=960,
        nprobe=np_)
    c1 = cluster_query_cost(TOS, w(nprobe), concurrency=conc)
    c2 = cluster_query_cost(TOS, w(nprobe * 2), concurrency=conc)
    assert c2["total"] >= c1["total"]           # more lists never cheaper
    c3 = cluster_query_cost(TOS, w(nprobe), concurrency=conc * 2)
    assert c3["total"] >= c1["total"]           # congestion never helps


@settings(max_examples=40, deadline=None)
@given(rt=st.integers(1, 64), w_=st.integers(1, 64))
def test_graph_cost_floor_is_rt_times_ttfb(rt, w_):
    g = GraphWorkloadPoint(roundtrips=rt, requests_per_round=w_,
                           node_nbytes=4096, R=64, pq_m=112, dim=960)
    c = graph_query_cost(TOS, g)
    assert c["total"] >= rt * TOS.ttfb_p50_s * 0.999
    # the same workload on SSD is strictly cheaper
    assert graph_query_cost(SSD, g)["total"] < c["total"]


@settings(max_examples=40, deadline=None)
@given(lat=st.floats(1e-4, 10.0), nbytes=st.floats(1e3, 1e9),
       req=st.floats(1, 1e4), conc=st.integers(1, 64))
def test_predicted_qps_respects_all_ceilings(lat, nbytes, req, conc):
    q = predicted_qps(TOS, lat, nbytes, req, conc)
    assert q <= conc / lat + 1e-6
    assert q <= TOS.bandwidth_Bps / nbytes + 1e-6
    assert q <= TOS.get_qps_limit / req + 1e-6


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(1_000, 10_000_000), min_size=1,
                      max_size=24),
       seed=st.integers(0, 100))
def test_storage_sim_conservation_and_ordering(sizes, seed):
    """Bytes are conserved; completions never precede their TTFB; the
    total wall time is at least total_bytes / bandwidth."""
    sim = StorageSim(TOS, seed=seed)
    for i, s in enumerate(sizes):
        sim.submit_batch(s, 1)
    done = sim.drain()
    assert len(done) == len(sizes)
    assert sim.total_bytes == sum(sizes)
    end = max(d.done_t for d in done)
    assert end >= sum(sizes) / TOS.bandwidth_Bps * 0.999
    for d in done:
        assert d.done_t >= d.start_t >= d.submit_t


@settings(max_examples=30, deadline=None)
@given(found=st.lists(st.integers(0, 50), min_size=10, max_size=10,
                      unique=True),
       true=st.lists(st.integers(0, 50), min_size=10, max_size=10,
                     unique=True))
def test_recall_bounds_and_identity(found, true):
    r = recall_at_k(np.asarray(found), np.asarray(true))
    assert 0.0 <= r <= 1.0
    assert recall_at_k(np.asarray(true), np.asarray(true)) == 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 4))
def test_roofline_scan_multiplier_scales(a, b, c):
    """Synthetic HLO: nested whiles multiply; entry factor is 1."""
    hlo = f"""
%cond_inner (p: (s32[])) -> pred[] {{
  %c = s32[] constant({a})
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}}
%body_inner (p: (s32[])) -> (s32[]) {{
  %ar = f32[4]{{0}} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[]) tuple(%ar)
}}
%cond_outer (p: (s32[])) -> pred[] {{
  %c2 = s32[] constant({b})
  ROOT %lt2 = pred[] compare(%iv2, %c2), direction=LT
}}
%body_outer (p: (s32[])) -> (s32[]) {{
  %w = (s32[]) while(%init), condition=%cond_inner, body=%body_inner
  ROOT %t2 = (s32[]) tuple(%w)
}}
ENTRY %main () -> s32[] {{
  %w2 = (s32[]) while(%init2), condition=%cond_outer, body=%body_outer
  ROOT %r = s32[] constant(0)
}}
"""
    mult = rf.computation_multipliers(hlo)
    assert mult["body_outer"] == b
    assert mult["body_inner"] == a * b
    coll = rf.collective_bytes_tripaware(hlo)
    naive = rf.collective_bytes(hlo)
    assert coll.get("all-reduce", 0) == naive.get("all-reduce", 0) * a * b
