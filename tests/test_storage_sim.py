import numpy as np
import pytest

from repro.storage.simulator import StorageSim, _SharedPipe
from repro.storage.spec import SSD, TOS, StorageSpec


def _quiet(spec: StorageSpec) -> StorageSpec:
    """Deterministic TTFB for unit checks."""
    import dataclasses
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


def _drain(sim: StorageSim):
    done = []
    while sim.busy:
        t = sim.next_event_time()
        done.extend(sim.advance_to(t))
    return done


def test_single_fetch_time():
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    nbytes = 10_000_000
    sim.submit_batch(0.0, nbytes, 1)
    (tk,) = _drain(sim)
    expect = spec.ttfb_p50_s + nbytes / spec.bandwidth_Bps + 1 / spec.get_qps_limit
    assert tk.done_t == pytest.approx(expect, rel=0.05)


def test_bandwidth_sharing_congestion():
    """Two concurrent transfers take ~2x as long as one (PS pipe)."""
    spec = _quiet(TOS)
    nbytes = 50_000_000
    sim1 = StorageSim(spec, seed=0)
    sim1.submit_batch(0.0, nbytes, 1)
    (solo,) = _drain(sim1)

    sim2 = StorageSim(spec, seed=0)
    sim2.submit_batch(0.0, nbytes, 1)
    sim2.submit_batch(0.0, nbytes, 1)
    both = _drain(sim2)
    t_solo = solo.done_t
    t_both = max(tk.done_t for tk in both)
    assert t_both > 1.7 * t_solo


def test_iops_throttling():
    """Admission of many requests is limited by get_qps_limit."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    n_req = 40_000                       # 2 seconds worth at 20k QPS
    sim.submit_batch(0.0, 1000, n_req)
    (tk,) = _drain(sim)
    assert tk.done_t >= n_req / spec.get_qps_limit


def test_iops_vs_ssd():
    """The same request flood is ~21x faster to admit on SSD (420k IOPS)."""
    n_req = 40_000
    t = {}
    for spec in [_quiet(TOS), _quiet(SSD)]:
        sim = StorageSim(spec, seed=0)
        sim.submit_batch(0.0, 1000, n_req)
        (tk,) = _drain(sim)
        t[spec.name] = tk.done_t
    assert t["volcano-tos"] > 10 * t["local-ssd"]


def test_ttfb_floor_dominates_small_reads():
    """4KB reads on TOS are TTFB-bound (paper: graph-index regime)."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    sim.submit_batch(0.0, 4096, 1)
    (tk,) = _drain(sim)
    transfer = 4096 / spec.bandwidth_Bps
    assert tk.done_t > 100 * transfer    # latency >> bandwidth term


def test_ttfb_lognormal_distribution():
    sim = StorageSim(TOS, seed=0)
    samples = np.array([sim.sample_ttfb() for _ in range(4000)])
    # median near p50; tail up to the 30-200ms cold range (§2.2)
    assert np.median(samples) == pytest.approx(TOS.ttfb_p50_s, rel=0.1)
    assert samples.max() > 3 * TOS.ttfb_p50_s
    assert (samples > 0).all()


def test_pipe_conservation():
    """PS pipe: total service time equals total bytes / bandwidth."""
    pipe = _SharedPipe(100.0)
    pipe.add(0.0, 1, 500.0)
    pipe.add(0.0, 2, 500.0)
    t1, tid1 = pipe.next_completion()
    pipe.complete(t1, tid1)
    t2, tid2 = pipe.next_completion()
    pipe.complete(t2, tid2)
    assert t2 == pytest.approx(1000.0 / 100.0)   # full drain at 10s


def test_deterministic_given_seed():
    for seed in [0, 7]:
        a = StorageSim(TOS, seed=seed)
        b = StorageSim(TOS, seed=seed)
        a.submit_batch(0.0, 1_000_000, 10)
        b.submit_batch(0.0, 1_000_000, 10)
        ta = _drain(a)[0].done_t
        tb = _drain(b)[0].done_t
        assert ta == tb
