"""Storage simulator invariants on the event kernel.

The sim is now a kernel component: submissions happen at the kernel's
current virtual time and completions are kernel events.  ``drain()``
runs a standalone sim's private kernel dry; stepped advancement goes
through ``sim.kernel.run_until``.
"""
import numpy as np
import pytest

from repro.storage.simulator import StorageSim, _SharedPipe
from repro.storage.spec import SSD, TOS, StorageSpec


def _quiet(spec: StorageSpec) -> StorageSpec:
    """Deterministic TTFB for unit checks."""
    import dataclasses
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


def test_single_fetch_time():
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    nbytes = 10_000_000
    sim.submit_batch(nbytes, 1)
    (tk,) = sim.drain()
    expect = spec.ttfb_p50_s + nbytes / spec.bandwidth_Bps + 1 / spec.get_qps_limit
    assert tk.done_t == pytest.approx(expect, rel=0.05)


def test_completion_callback_fires_at_done_time():
    """on_done fires at the completion event, at the ticket's done_t."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    seen = []
    sim.submit_batch(1_000_000, 1,
                     on_done=lambda tk: seen.append((sim.kernel.now, tk)))
    sim.kernel.run()
    ((t_cb, tk),) = seen
    assert t_cb == tk.done_t
    assert not sim.completed                 # callback tickets don't pile up


def test_bandwidth_sharing_congestion():
    """Two concurrent transfers take ~2x as long as one (PS pipe)."""
    spec = _quiet(TOS)
    nbytes = 50_000_000
    sim1 = StorageSim(spec, seed=0)
    sim1.submit_batch(nbytes, 1)
    (solo,) = sim1.drain()

    sim2 = StorageSim(spec, seed=0)
    sim2.submit_batch(nbytes, 1)
    sim2.submit_batch(nbytes, 1)
    both = sim2.drain()
    t_solo = solo.done_t
    t_both = max(tk.done_t for tk in both)
    assert t_both > 1.7 * t_solo


def test_iops_throttling():
    """Admission of many requests is limited by get_qps_limit."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    n_req = 40_000                       # 2 seconds worth at 20k QPS
    sim.submit_batch(1000, n_req)
    (tk,) = sim.drain()
    assert tk.done_t >= n_req / spec.get_qps_limit


def test_iops_vs_ssd():
    """The same request flood is ~21x faster to admit on SSD (420k IOPS)."""
    n_req = 40_000
    t = {}
    for spec in [_quiet(TOS), _quiet(SSD)]:
        sim = StorageSim(spec, seed=0)
        sim.submit_batch(1000, n_req)
        (tk,) = sim.drain()
        t[spec.name] = tk.done_t
    assert t["volcano-tos"] > 10 * t["local-ssd"]


def test_ttfb_floor_dominates_small_reads():
    """4KB reads on TOS are TTFB-bound (paper: graph-index regime)."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    sim.submit_batch(4096, 1)
    (tk,) = sim.drain()
    transfer = 4096 / spec.bandwidth_Bps
    assert tk.done_t > 100 * transfer    # latency >> bandwidth term


def test_ttfb_lognormal_distribution():
    sim = StorageSim(TOS, seed=0)
    samples = np.array([sim.sample_ttfb() for _ in range(4000)])
    # median near p50; tail up to the 30-200ms cold range (§2.2)
    assert np.median(samples) == pytest.approx(TOS.ttfb_p50_s, rel=0.1)
    assert samples.max() > 3 * TOS.ttfb_p50_s
    assert (samples > 0).all()


def test_pipe_conservation():
    """PS pipe: total service time equals total bytes / bandwidth."""
    pipe = _SharedPipe(100.0)
    pipe.add(0.0, 1, 500.0)
    pipe.add(0.0, 2, 500.0)
    t1, tid1 = pipe.next_completion()
    pipe.complete(t1, tid1)
    t2, tid2 = pipe.next_completion()
    pipe.complete(t2, tid2)
    assert t2 == pytest.approx(1000.0 / 100.0)   # full drain at 10s


def test_token_bucket_get_ceiling_under_burst():
    """A burst of batches is admitted at no more than get_qps_limit:
    consecutive admission times are spaced >= n_requests / limit."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    n_req = 100
    tickets = [sim.submit_batch(1000, n_req) for _ in range(50)]
    sim.drain()
    # start_t = admission + ttfb (deterministic here) => spacing is pure
    # token-bucket admission
    starts = np.array(sorted(t.start_t for t in tickets))
    min_gap = n_req / spec.get_qps_limit
    assert (np.diff(starts) >= min_gap * (1 - 1e-6)).all()
    # aggregate: the whole burst cannot beat the IOPS ceiling
    total = n_req * len(tickets)
    assert starts[-1] - starts[0] >= \
        (total - n_req) / spec.get_qps_limit * (1 - 1e-6)


def test_processor_sharing_equal_split():
    """K equal transfers admitted together each get bandwidth/K: all
    finish at ~K times the solo transfer time."""
    spec = _quiet(TOS)
    nbytes = 20_000_000
    solo = StorageSim(spec, seed=0)
    solo.submit_batch(nbytes, 1)
    (tk,) = solo.drain()
    t_solo_transfer = nbytes / spec.bandwidth_Bps
    for k in (2, 4):
        sim = StorageSim(spec, seed=0)
        for _ in range(k):
            sim.submit_batch(nbytes, 1)
        done = sim.drain()
        # all K share the pipe for the whole transfer -> finish together
        # (modulo the staggered token-bucket admissions at 1/get_qps_limit)
        ends = [t.done_t for t in done]
        assert max(ends) - min(ends) < 0.01 * max(ends)
        expect = tk.done_t - t_solo_transfer + k * t_solo_transfer
        assert max(ends) == pytest.approx(expect, rel=0.05)


def test_processor_sharing_staggered_arrival():
    """Exact PS arithmetic with a mid-transfer arrival."""
    pipe = _SharedPipe(100.0)
    pipe.add(0.0, 1, 1000.0)          # alone: 0-5s at 100 B/s -> 500 left
    pipe.add(5.0, 2, 500.0)           # now both at 50 B/s
    t1, tid1 = pipe.next_completion()
    # both have 500 bytes left at t=5, both finish at t=15
    assert t1 == pytest.approx(15.0)
    pipe.complete(t1, tid1)
    t2, _ = pipe.next_completion()
    assert t2 == pytest.approx(15.0)


def test_advance_cadence_invariance():
    """The same submission schedule produces the same completions (order
    exactly, times to 1e-9 relative) whether the kernel runs dry in one
    go or is stepped forward in many small run_until increments."""
    spec = TOS                          # noisy TTFB included
    schedule = [(0.0, 3_000_000, 4), (0.001, 500_000, 2),
                (0.002, 8_000_000, 8), (0.01, 4096, 1)]

    def run(step: float | None):
        sim = StorageSim(spec, seed=42)
        for t, nb, nr in schedule:
            sim.kernel.run_until(t)
            sim.submit_batch(nb, nr)
        if step is None:
            sim.kernel.run()
        else:
            t = 0.01
            while sim.busy:
                t += step
                sim.kernel.run_until(t)
        done = sim.completed
        return sorted((d.batch_id, d.done_t) for d in done)

    coarse = run(None)
    fine = run(1e-4)
    assert [c[0] for c in coarse] == [f[0] for f in fine]
    for (_, tc), (_, tf) in zip(coarse, fine):
        assert tc == pytest.approx(tf, rel=1e-9)


def test_abort_all_drops_inflight_work():
    """abort_all forgets queued + in-flight transfers; the kernel drains
    with no completions and later submissions still work."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    sim.submit_batch(10_000_000, 4)
    sim.submit_batch(5_000_000, 2)
    sim.kernel.run_until(spec.ttfb_p50_s * 1.5)   # first transfer started
    assert sim.busy
    sim.abort_all()
    assert not sim.busy
    sim.kernel.run()
    assert sim.completed == []
    sim.submit_batch(1_000_000, 1)
    (tk,) = sim.drain()
    assert tk.done_t > sim.kernel.now - 1e-9 or tk.done_t > 0


def test_abort_all_refunds_unstarted_get_tokens():
    """Batches killed before transfer start give their GET tokens back:
    post-fault traffic must not queue behind phantom admissions."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    n_req = 20_000                        # 1 s of tokens per batch
    for _ in range(5):
        sim.submit_batch(1000, n_req)     # 5 s of bucket time reserved
    sim.abort_all()                       # t=0: nothing reached _start
    tk = sim.submit_batch(1000, 1)
    sim.drain()
    # admission is this batch's own token only, not 5 s of dead work
    expect = (1 / spec.get_qps_limit + spec.ttfb_p50_s
              + spec.min_latency_s)
    assert tk.start_t == pytest.approx(expect, rel=0.05)


def test_abort_all_refund_spares_started_batches():
    """Tokens are spent at transfer start: a batch already on the pipe
    when the fault hits keeps its charge; only unstarted ones refund."""
    spec = _quiet(TOS)
    sim = StorageSim(spec, seed=0)
    n_req = 20_000                        # 1 s of tokens
    first = sim.submit_batch(1000, n_req)
    sim.submit_batch(1000, n_req)         # queued behind the first
    sim.kernel.run_until(first.start_t + 1e-9)   # first is transferring
    assert sim.pipe.active
    sim.abort_all()
    tk = sim.submit_batch(1000, 1)
    sim.drain()
    # the second batch's 1 s refunded; the first's stays spent, but the
    # bucket clock never falls behind wall time, so admission is prompt
    expect = (1 / spec.get_qps_limit + spec.ttfb_p50_s
              + spec.min_latency_s)
    assert tk.start_t - tk.submit_t == pytest.approx(expect, rel=0.05)


def test_fault_replay_with_and_without_hedging():
    """End-to-end abort-refund regression: replay one fault schedule
    through the fleet with hedging off and on.  Every arrival completes
    with exact results (no query starves behind refunded tokens), and
    each replay is bit-identical to its twin — abort bookkeeping leaks
    would show up as nondeterministic admission times."""
    import dataclasses

    from repro.core.cluster_index import ClusterIndex
    from repro.core.flat import exact_topk
    from repro.core.types import ClusterIndexParams, SearchParams
    from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
    from repro.fleet import FleetConfig, run_fleet
    from repro.sim.arrivals import Poisson
    from repro.sim.faults import FaultSchedule, ShardFault

    data, queries = make_dataset(scaled(DEEP_ANALOG, 600, 16))
    gt, _ = exact_topk(data, queries, 10)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                     seed=0))
    p = SearchParams(k=10, nprobe=16)
    heavy = dataclasses.replace(TOS, ttfb_sigma=0.8)
    faults = FaultSchedule((ShardFault(shard=0, t_fail=0.05,
                                       t_recover=0.25),
                            ShardFault(shard=1, t_fail=0.15,
                                       t_recover=0.30)))
    for hedge in (False, True):
        cfg = FleetConfig(n_shards=2, replication=2, storage=heavy,
                          concurrency=12, shard_concurrency=4,
                          queue_depth=32, seed=6, hedge=hedge,
                          hedge_percentile=70.0, hedge_min_samples=16)
        runs = [run_fleet(ci, queries, p, cfg,
                          arrivals=Poisson(rate_qps=200.0,
                                           n_total=2 * len(queries)),
                          faults=faults) for _ in range(2)]
        for rep in runs:
            assert len(rep.records) == rep.n_arrivals
            assert all((r.ids >= 0).all() for r in rep.records)
            assert rep.recall_against(gt) == \
                runs[0].recall_against(gt)
        a, b = runs
        assert a.wall_time_s == b.wall_time_s
        assert sorted((r.qid, r.sojourn) for r in a.records) == \
            sorted((r.qid, r.sojourn) for r in b.records)


def test_workload_replay_concurrency_invariance():
    """Replaying the same workload at different concurrency changes
    timing but is bit-for-bit identical in results and total traffic."""
    from repro.core.cluster_index import ClusterIndex
    from repro.core.types import ClusterIndexParams, SearchParams
    from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
    from repro.serving.engine import run_workload

    data, queries = make_dataset(scaled(DEEP_ANALOG, 600, 16))
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                     seed=0))
    p = SearchParams(k=10, nprobe=16)
    reps = [run_workload(ci, queries, p, TOS, concurrency=c, seed=0,
                         cache_policy="none") for c in (1, 4, 16)]
    base = {r.qid: r for r in reps[0].records}
    for rep in reps[1:]:
        assert rep.storage_bytes == reps[0].storage_bytes
        assert rep.storage_requests == reps[0].storage_requests
        for rec in rep.records:
            np.testing.assert_array_equal(rec.ids, base[rec.qid].ids)
            np.testing.assert_array_equal(rec.dists, base[rec.qid].dists)


def test_deterministic_given_seed():
    for seed in [0, 7]:
        a = StorageSim(TOS, seed=seed)
        b = StorageSim(TOS, seed=seed)
        a.submit_batch(1_000_000, 10)
        b.submit_batch(1_000_000, 10)
        ta = a.drain()[0].done_t
        tb = b.drain()[0].done_t
        assert ta == tb
