import numpy as np
import pytest

try:                         # optional dep: only the property test needs it
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

from repro.cache.slru import PinnedCache, SLRUCache


def test_basic_hit_miss():
    c = SLRUCache(100)
    assert not c.get("a")
    c.put("a", 40)
    assert c.get("a")
    assert c.hit_rate == 0.5


def test_eviction_at_capacity():
    c = SLRUCache(100)
    for i in range(5):
        c.put(i, 40)
    assert c.used_bytes <= 100


def test_scan_resistance():
    """A one-time scan must not evict the protected working set."""
    c = SLRUCache(1000, protected_frac=0.8)
    for i in range(10):
        c.put(("hot", i), 50)
        c.get(("hot", i))        # promote to protected
    for j in range(100):         # huge scan of cold keys
        c.get(("cold", j))
        c.put(("cold", j), 50)
    hot_alive = sum(1 for i in range(10) if ("hot", i) in c)
    assert hot_alive >= 8


def test_protected_demotion_not_eviction():
    c = SLRUCache(200, protected_frac=0.5)
    for i in range(4):
        c.put(i, 50)
        c.get(i)                 # all promoted; protected cap = 100 -> demote
    assert c.protected_bytes <= 100
    assert c.used_bytes <= 200


def test_zero_capacity_never_hits():
    c = SLRUCache(0)
    c.put("a", 10)
    assert not c.get("a")


def test_oversized_object_rejected():
    c = SLRUCache(100)
    c.put("big", 500)
    assert "big" not in c


def test_demotion_keeps_demoted_key_resident():
    """Protected overflow demotes the protected-LRU key back to probation
    — it must remain cached (demotion is not eviction)."""
    c = SLRUCache(200, protected_frac=0.5)     # protected cap = 100
    c.put("a", 60)
    assert c.get("a")                          # "a" -> protected (60B)
    c.put("b", 60)
    assert c.get("b")                          # promote "b": 120B > 100B
    assert "a" in c.probation                  # LRU protected key demoted
    assert "a" not in c.protected
    assert "b" in c.protected
    assert "a" in c                            # still served from cache
    assert c.get("a")                          # re-promotes, demoting "b"
    assert "b" in c.probation and "a" in c.protected


def test_demotion_cascade_respects_total_capacity():
    """Demoted keys land in probation and may push probation evictions,
    but total bytes never exceed capacity and protected never exceeds
    its segment cap."""
    c = SLRUCache(300, protected_frac=0.5)
    for i in range(6):
        c.put(i, 90)
        c.get(i)                               # promote each in turn
        assert c.protected_bytes <= 150
        assert c.used_bytes <= 300
    # the most recently promoted key survives in protected
    assert 5 in c.protected


def test_pinned_cache():
    p = PinnedCache({1, 2})
    assert p.get(1) and p.get(2) and not p.get(3)
    p.put(3, 10)
    assert not p.get(3)          # contents fixed


if given is not None:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 30), st.integers(1, 50)),
                    min_size=1, max_size=200),
           st.integers(50, 400))
    def test_slru_invariants(ops, cap):
        """Property: byte accounting is exact and capacity never exceeded."""
        c = SLRUCache(cap)
        for key, size in ops:
            if not c.get(key):
                c.put(key, size)
            assert c.used_bytes <= cap
            assert c.probation_bytes == sum(c.probation.values())
            assert c.protected_bytes == sum(c.protected.values())
            # no key in both segments
            assert not (set(c.probation) & set(c.protected))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_slru_invariants():
        pass
