"""repro.ingest: delta tier, merged search, compaction, rw scenario.

Covers the churn-correctness acceptance set: tombstones never surface,
delta+sealed recall matches a rebuilt index after full compaction,
replay is deterministic under the kernel, and the zero-write rw path is
bit-identical to the pure-query golden reports.
"""
import dataclasses
import hashlib
import json
import os

import numpy as np
import pytest

try:                         # optional dep for the property test
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    given = None

from repro.core.cluster_index import ClusterIndex
from repro.core.cost_model import ComputeSpec
from repro.core.graph_index import GraphIndex
from repro.core.types import (ClusterIndexParams, GraphIndexParams,
                              SearchParams, recall_at_k)
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.fleet.partition import ClusterPartition, GraphPartition
from repro.ingest import (IngestAgent, IngestConfig, IngestReport,
                          Memtable, UpdateStream, churn_ground_truth,
                          make_mutable, synth_updates)
from repro.serving.engine import run_workload
from repro.sim.admission import AdmissionWindow
from repro.sim.arrivals import Scenario
from repro.sim.kernel import Kernel
from repro.storage.simulator import StorageSim
from repro.storage.spec import TOS

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")


def _quiet(spec):
    return dataclasses.replace(spec, ttfb_sigma=1e-9)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    return data, queries


def _cluster(data):
    return ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4,
                                                       seed=0))


def _graph(data):
    return GraphIndex.build(data, GraphIndexParams(
        R=24, L_build=48, build_passes=1, pq_dims=24, seed=0))


def _drain(mutable, seed=7):
    """Force-flush every site's delta through a private kernel."""
    kernel = Kernel(seed=seed)
    sim = StorageSim(TOS, kernel, seed=seed)
    for sid in sorted(mutable.sites):
        agent = IngestAgent(mutable, site_id=sid, kernel=kernel,
                            cfg=IngestConfig(), compute=ComputeSpec(),
                            sim_provider=lambda: sim,
                            report=IngestReport())
        agent.flush_now()
    kernel.run()


# ------------------------------------------------------------- memtable --

def test_memtable_bytes_and_tombstones():
    m = Memtable(vec_nbytes=32)
    assert m.used_bytes == 0
    m.insert(1, np.ones(8, np.float32), (0, 2), 0.0, 0.0)
    assert m.used_bytes == 40
    assert not m.delete(5, 0.1)          # sealed id -> tombstone
    assert m.used_bytes == 48
    assert m.delete(1, 0.2)              # delta id -> vanishes outright
    assert len(m) == 0 and 1 not in m.tombstones
    m.insert(5, np.ones(8, np.float32), (0,), 0.3, 0.3)
    assert 5 not in m.tombstones         # re-insert resurrects


def test_memtable_search_and_list_restriction():
    m = Memtable(vec_nbytes=8)
    m.insert(10, np.array([0.0, 0.0]), (0,), 0.0, 0.0)
    m.insert(11, np.array([1.0, 1.0]), (1,), 0.0, 0.0)
    ids, d, n = m.search(np.zeros(2), k=5)
    assert list(ids) == [10, 11] and n == 2
    ids, _, _ = m.search(np.zeros(2), k=5, lists=(1,))
    assert list(ids) == [11]


# ------------------------------------------------------------ admission --

def test_admission_window_order_and_drain():
    k = Kernel()
    started = []
    adm = AdmissionWindow(k, 2, lambda item, t: started.append((item, t)))
    assert adm.offer("a") and adm.offer("b")
    assert not adm.offer("c")            # windows full -> backlog
    assert adm.depth == 1
    adm.release(1.5)                     # pops c at the completion time
    assert started == [("a", 0.0), ("b", 0.0), ("c", 1.5)]
    adm.release(2.0)
    adm.release(2.5)
    assert adm.idle and not adm.drained
    adm.mark_exhausted()
    assert adm.drained
    assert adm.arrivals_total == 3


# -------------------------------------------------------------- caches ---

def test_slru_remove_fixes_byte_accounting():
    from repro.cache.slru import SLRUCache
    c = SLRUCache(1000)
    c.put("a", 100)
    c.put("b", 200)
    assert c.get("a")                    # promote a to protected
    freed = c.remove("a")
    assert freed == 100 and "a" not in c
    assert c.used_bytes == 200 and c.protected_bytes == 0
    assert c.remove("b") == 200 and c.used_bytes == 0
    assert c.remove("zzz") == 0
    c.put("d", 50)
    assert c.invalidate("d") and not c.invalidate("d")


def test_pinned_remove_unpins():
    from repro.cache.slru import PinnedCache
    c = PinnedCache({"x", "y"})
    assert c.get("x")
    assert c.invalidate("x")
    assert not c.get("x")                # stale pin no longer hits


# ------------------------------------------------- merged-search churn ---

def test_merged_search_never_returns_deleted(setup):
    data, queries = setup
    mci = make_mutable(_cluster(data))
    p = SearchParams(k=10, nprobe=16)
    base = mci.search(queries[0], p)
    victims = [int(i) for i in base.ids[:4]]
    for v in victims:
        mci.site(0).delete(v, 0.0)
        mci.note_delete(v)
    res = mci.search(queries[0], p)
    assert not set(int(i) for i in res.ids) & set(victims)
    # still k results padded sanely
    assert len(res.ids) == 10


def test_delta_insert_is_immediately_searchable(setup):
    data, queries = setup
    mci = make_mutable(_cluster(data))
    p = SearchParams(k=10, nprobe=16)
    q = queries[1]
    new_id = len(data) + 17
    lists, _ = mci.assign_lists(q)
    mci.site(0).insert(new_id, q.copy(), lists, 0.0, 0.0)
    mci.note_insert(new_id)
    res = mci.search(q, p)
    assert int(res.ids[0]) == new_id     # the exact-match insert wins


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 1199), min_size=1, max_size=24),
           st.integers(0, 31))
    def test_property_tombstones_never_surface(victims, qi):
        data, queries = make_dataset(scaled(DEEP_ANALOG, 1200, 32))
        mci = getattr(test_property_tombstones_never_surface, "_mci",
                      None)
        if mci is None:
            mci = make_mutable(_cluster(data))
            test_property_tombstones_never_surface._mci = mci
        # reset per-example churn state
        mci.site(0).tombstones.clear()
        mci.deleted.clear()
        mci._deleted_arr = None
        for v in victims:
            mci.site(0).delete(v, 0.0)
            mci.note_delete(v)
        res = mci.search(queries[qi], SearchParams(k=10, nprobe=16))
        assert not set(int(i) for i in res.ids) & set(victims)


# -------------------------------------------- compaction == rebuild ------

def test_full_compaction_matches_rebuilt_cluster(setup):
    data, queries = setup
    mci = make_mutable(_cluster(data))
    p = SearchParams(k=10, nprobe=32)
    stream = synth_updates(data, rate_qps=500.0, n_updates=150,
                           delete_frac=0.3, seed=3)
    run_workload(mci, queries, p, _quiet(TOS), concurrency=8, seed=0,
                 updates=stream,
                 ingest=IngestConfig(delta_cap_bytes=32 * 1024))
    _drain(mci)
    assert mci.delta_bytes == 0
    gt = churn_ground_truth(data, stream, queries, 10)
    merged = [mci.search(q, p) for q in queries]
    rec_m = np.mean([recall_at_k(r.ids[r.ids >= 0], gt[i])
                     for i, r in enumerate(merged)])
    # rebuilt reference on the churned corpus
    from repro.ingest import churned_corpus
    corpus, ids = churned_corpus(data, stream)
    rebuilt = _cluster(corpus)
    rec_r = np.mean([recall_at_k(ids[r.ids[r.ids >= 0]], gt[i])
                     for i, r in enumerate(
                         rebuilt.search(q, p) for q in queries)])
    assert rec_m >= rec_r - 0.05
    # no tombstoned id anywhere
    dead = {op.id for op in stream.ops if op.kind == "delete"}
    reborn = {op.id for op in stream.ops if op.kind == "insert"}
    for r in merged:
        assert not set(int(i) for i in r.ids) & (dead - reborn)


def test_full_compaction_matches_rebuilt_graph():
    data, queries = make_dataset(scaled(DEEP_ANALOG, 900, 24))
    gi = _graph(data)
    p = SearchParams(k=10, search_len=40, beamwidth=8)
    stream = synth_updates(data, rate_qps=500.0, n_updates=80,
                           delete_frac=0.25, seed=2,
                           protected=frozenset([gi.meta.medoid]))
    mgi = make_mutable(gi)
    run_workload(mgi, queries, p, _quiet(TOS), concurrency=8, seed=0,
                 updates=stream,
                 ingest=IngestConfig(delta_cap_bytes=16 * 1024))
    _drain(mgi)
    assert mgi.delta_bytes == 0
    gt = churn_ground_truth(data, stream, queries, 10)
    merged = [mgi.search(q, p) for q in queries]
    rec_m = np.mean([recall_at_k(r.ids[r.ids >= 0], gt[i])
                     for i, r in enumerate(merged)])
    from repro.ingest import churned_corpus
    corpus, ids = churned_corpus(data, stream)
    rebuilt = _graph(corpus)
    rec_r = np.mean([recall_at_k(ids[r.ids[r.ids >= 0]], gt[i])
                     for i, r in enumerate(
                         rebuilt.search(q, p) for q in queries)])
    assert rec_m >= rec_r - 0.05
    dead = {op.id for op in stream.ops if op.kind == "delete"}
    for r in merged:
        assert not set(int(i) for i in r.ids) & dead


# ----------------------------------------------------------- overflow ----

def test_overflowed_list_reclusters(setup):
    data, queries = setup
    mci = make_mutable(_cluster(data))
    n_lists0 = mci.meta.n_lists
    p = SearchParams(k=10, nprobe=16)
    # aim a dense clump of inserts at one query's neighbourhood
    q = queries[0]
    rng = np.random.default_rng(0)
    ops = []
    t = 0.0
    for i in range(200):
        t += 1e-3
        vec = (q + rng.normal(0, 0.01, size=q.shape)).astype(data.dtype)
        ops.append(dataclasses.replace(
            synth_updates(data, 1.0, 1, delete_frac=0.0, seed=i).ops[0],
            t=t, seq=i, id=len(data) + i, vec=vec))
    stream = UpdateStream(ops)
    run_workload(mci, queries, p, _quiet(TOS), concurrency=4, seed=0,
                 updates=stream,
                 ingest=IngestConfig(delta_cap_bytes=16 * 1024,
                                     overflow_factor=1.5))
    _drain(mci)
    assert mci.meta.n_lists > n_lists0   # at least one split happened
    # the split lists stay routable and the clump is findable
    res = mci.search(q, p)
    assert int(res.ids[0]) >= len(data)


# ------------------------------------------------------- partitions ------

def test_cluster_partition_inherit_and_graph_growth(setup):
    data, _ = setup
    ci = _cluster(data)
    part = ClusterPartition.build(ci.meta.list_nbytes, 4, 2)
    n0 = len(part.owners_arr)
    parent_owners = part.owners(("list", 3))
    part.inherit(n0, 3)
    assert part.owners(("list", n0)) == parent_owners
    with pytest.raises(ValueError):
        part.inherit(n0 + 5, 0)          # non-contiguous
    gp = GraphPartition.build(100, 4, 2, seed=1)
    grown = gp.owners(("node", 10_000))  # beyond the build range
    assert len(set(grown)) == 2
    assert all(0 <= s < 4 for s in grown)
    assert gp.owners(("node", 10_000)) == grown   # stable


# ------------------------------------------------------ rw scenario ------

def test_rw_zero_writes_reproduces_golden(setup):
    """Acceptance: the rw path at write rate 0 — mutable wrapper, rw
    scenario, full ingest plumbing — reproduces the pre-ingest
    closed-loop golden reports bit-exactly."""
    data, queries = setup
    golden = json.load(open(GOLDEN_PATH))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    scen = Scenario(kind="rw", write_rate_qps=0.0)
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64,
                              seed=0),
        four_shard=FleetConfig(n_shards=4, replication=2, concurrency=16,
                               shard_concurrency=4, queue_depth=16,
                               hedge=True, hedge_percentile=75.0, seed=5))
    for name, cfg in configs.items():
        mci = make_mutable(_cluster(data))
        arr = scen.make_arrivals(len(queries), cfg.concurrency,
                                 seed=cfg.seed)
        updates = scen.make_updates(data, seed=cfg.seed)
        assert updates is None           # zero rate -> no stream at all
        rep = run_fleet(mci, queries, p, cfg, arrivals=arr,
                        updates=updates)
        g = golden[name]
        assert rep.wall_time_s == pytest.approx(g["wall_time_s"],
                                                rel=1e-9, abs=1e-12)
        assert rep.qps == pytest.approx(g["qps"], rel=1e-9)
        h = hashlib.sha256()
        for r in sorted(rep.records, key=lambda r: r.qid):
            h.update(np.asarray(r.qid).tobytes())
            h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
        assert h.hexdigest() == g["ids_sha256"]
        assert rep.ingest is None


def test_rw_fleet_deterministic_and_fresh(setup):
    data, queries = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=3, replication=2, concurrency=8, seed=1)

    def once():
        stream = synth_updates(data, 600.0, 120, delete_frac=0.3, seed=3)
        rep = run_fleet(make_mutable(_cluster(data)), queries, p, cfg,
                        updates=stream,
                        ingest=IngestConfig(delta_cap_bytes=24 * 1024))
        return rep, stream

    a, stream = once()
    b, _ = once()
    assert a.to_json() == b.to_json()    # bit-exact replay
    ing = a.ingest
    assert ing["flushes"] > 0
    assert ing["write_amplification"] > 1.0
    assert ing["visibility_lag"]["mean_s"] > 0
    assert ing["seal_lag"]["n"] > 0
    assert ing["compaction_read_bytes"] > 0
    # every applied delete is honoured by queries that finish after the
    # stream ends
    t_end = max(op.t for op in stream.ops)
    dead = {op.id for op in stream.ops if op.kind == "delete"}
    reborn = {op.id for op in stream.ops if op.kind == "insert"}
    for r in a.records:
        if r.start_t > t_end:
            assert not set(int(i) for i in r.ids) & (dead - reborn)


def test_compaction_contends_with_queries(setup):
    """Compaction I/O goes through the serving sims: a write-heavy run
    must show slower queries than the same read load without writes."""
    data, queries = setup
    p = SearchParams(k=10, nprobe=32)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=2)
    stream = synth_updates(data, rate_qps=3000.0, n_updates=600,
                           delete_frac=0.2, seed=5)
    arr = Scenario(kind="rw", n_arrivals=4 * len(queries))
    quiet = run_fleet(
        make_mutable(_cluster(data)), queries, p, cfg,
        arrivals=arr.make_arrivals(len(queries), cfg.concurrency))
    churn = run_fleet(
        make_mutable(_cluster(data)), queries, p, cfg,
        arrivals=arr.make_arrivals(len(queries), cfg.concurrency),
        updates=stream,
        ingest=IngestConfig(delta_cap_bytes=16 * 1024,
                            recluster=False))
    ing = churn.ingest
    assert ing["queries_during_compaction"] > 0
    assert churn.wall_time_s > quiet.wall_time_s
    assert ing["query_p99_during_compaction_s"] > 0


def test_freshness_lag_grows_with_delta_capacity(setup):
    data, queries = setup
    p = SearchParams(k=10, nprobe=16)

    def seal_lag(cap):
        stream = synth_updates(data, 800.0, 200, delete_frac=0.2, seed=6)
        rep = run_workload(make_mutable(_cluster(data)), queries, p,
                           _quiet(TOS), concurrency=8, seed=0,
                           updates=stream,
                           ingest=IngestConfig(delta_cap_bytes=cap))
        return rep.ingest["seal_lag"]

    small = seal_lag(8 * 1024)
    big = seal_lag(128 * 1024)
    assert small["n"] > 0
    assert big["n"] == 0 or big["mean_s"] > small["mean_s"]


def test_rw_cache_invalidation_serves_fresh_content(setup):
    data, queries = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=3,
                      cache_bytes=1 << 30, cache_policy="slru")
    stream = synth_updates(data, 600.0, 120, delete_frac=0.3, seed=7)
    arr = Scenario(kind="rw", n_arrivals=3 * len(queries))
    rep = run_fleet(make_mutable(_cluster(data)), queries, p, cfg,
                    arrivals=arr.make_arrivals(len(queries),
                                               cfg.concurrency),
                    updates=stream,
                    ingest=IngestConfig(delta_cap_bytes=16 * 1024))
    assert rep.hit_rate > 0.2            # the cache did serve
    t_end = max(op.t for op in stream.ops)
    dead = {op.id for op in stream.ops if op.kind == "delete"}
    reborn = {op.id for op in stream.ops if op.kind == "insert"}
    for r in rep.records:                # stale cached lists never leak
        if r.start_t > t_end:            # deleted ids back in
            assert not set(int(i) for i in r.ids) & (dead - reborn)


def test_scenario_rw_validation_and_stream_synth(setup):
    data, _ = setup
    with pytest.raises(ValueError):
        Scenario(kind="rw", write_rate_qps=-1.0)
    with pytest.raises(ValueError):
        Scenario(kind="rw", delete_frac=1.0)
    s = Scenario(kind="rw", write_rate_qps=100.0, n_updates=50,
                 delete_frac=0.3)
    stream = s.make_updates(data, seed=0)
    assert len(stream) == 50
    assert stream.n_inserts + stream.n_deletes == 50
    assert stream.n_deletes > 0
    # deterministic
    stream2 = s.make_updates(data, seed=0)
    assert [(op.t, op.kind, op.id) for op in stream.ops] == \
        [(op.t, op.kind, op.id) for op in stream2.ops]
    # deletes only target live ids
    live = set(range(len(data)))
    for op in stream.ops:
        if op.kind == "insert":
            live.add(op.id)
        else:
            assert op.id in live
            live.discard(op.id)


# ---------------------------------------------------- space reclamation --

def test_retired_graph_blocks_are_reclaimed():
    """PR-4 follow-up: compaction used to leave deleted node blocks as
    unreachable garbage in the ObjectStore.  Retirement now *unlinks*
    them (bytes reclaimed immediately; the payload lingers readable for
    in-flight pre-compaction readers and is purged at the next flush),
    so after full compaction the store byte-size converges to exactly
    live_count x node_nbytes."""
    data, queries = make_dataset(scaled(DEEP_ANALOG, 900, 24))
    gi = _graph(data)
    node_nb = gi.meta.node_nbytes
    assert gi.store.total_bytes == gi.meta.n_data * node_nb
    p = SearchParams(k=10, search_len=40, beamwidth=8)
    stream = synth_updates(data, rate_qps=500.0, n_updates=80,
                           delete_frac=0.25, seed=2,
                           protected=frozenset([gi.meta.medoid]))
    mgi = make_mutable(gi)
    run_workload(mgi, queries, p, _quiet(TOS), concurrency=8, seed=0,
                 updates=stream,
                 ingest=IngestConfig(delta_cap_bytes=16 * 1024))
    _drain(mgi)
    assert mgi.delta_bytes == 0
    assert len(mgi.dead) > 0             # the scenario really deletes
    # convergence: billed bytes == live nodes, no dead key reachable
    assert mgi.store.total_bytes == mgi.live_count * node_nb
    assert len(mgi.store) == mgi.live_count
    for d in mgi.dead:
        assert ("node", d) not in mgi.store
    # lingering corpses are purged by the next flush cycle
    mgi.store.purge_lingering()
    assert mgi.store.lingering_count == 0
    for d in mgi.dead:
        with pytest.raises(KeyError):
            mgi.store.get(("node", d))
    # queries still work against the compacted store
    res = mgi.search(queries[0], p)
    assert len(res.ids) == 10
    assert not set(int(i) for i in res.ids) & mgi.dead


def test_unlink_keeps_inflight_reads_alive():
    from repro.storage.object_store import ObjectStore
    store = ObjectStore()
    store.put("a", ("payload",), 100)
    assert store.total_bytes == 100
    assert store.unlink("a") == 100
    assert store.total_bytes == 0 and "a" not in store
    assert store.get("a") == ("payload",)        # lingering reader
    assert store.unlink("a") == 0                # idempotent
    store.put("a", ("fresh",), 50)               # re-insert supersedes
    assert store.get("a") == ("fresh",) and store.total_bytes == 50
    store.unlink("a")
    assert store.purge_lingering() == 1
    with pytest.raises(KeyError):
        store.get("a")


# --------------------------------------------- invariant sweep (churn) ---

def _mini_index(kind: str, data):
    if kind == "cluster":
        return make_mutable(ClusterIndex.build(
            data, ClusterIndexParams(kmeans_iters=3, seed=0)))
    return make_mutable(GraphIndex.build(
        data, GraphIndexParams(R=16, L_build=24, build_passes=1,
                               pq_dims=16, seed=0)))


def _mini_params(kind: str) -> SearchParams:
    if kind == "cluster":
        return SearchParams(k=5, nprobe=8)
    return SearchParams(k=5, search_len=16, beamwidth=4)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("kind", ["cluster", "graph"])
@pytest.mark.parametrize("scenario", ["closed", "poisson", "rw"])
def test_determinism_matrix_replay_is_byte_identical(seed, kind, scenario):
    """Cross-seed determinism sweep: every (seed x index kind x
    scenario) cell replays to a byte-identical report."""
    data, queries = make_dataset(scaled(DEEP_ANALOG, 360, 10, seed=seed))

    def once() -> str:
        index = _mini_index(kind, data)
        p = _mini_params(kind)
        scen = Scenario(kind=scenario, rate_qps=300.0,
                        n_arrivals=2 * len(queries),
                        write_rate_qps=400.0 if scenario == "rw" else 0.0,
                        n_updates=40, delete_frac=0.25)
        arrivals = scen.make_arrivals(len(queries), 4, seed=seed)
        updates = scen.make_updates(
            data, seed=seed,
            protected=(frozenset([index.meta.medoid])
                       if kind == "graph" else None))
        rep = run_workload(index, queries, p, _quiet(TOS), concurrency=4,
                           seed=seed, arrivals=arrivals, updates=updates,
                           ingest=IngestConfig(delta_cap_bytes=8 * 1024))
        h = hashlib.sha256()
        for r in sorted(rep.records, key=lambda r: (r.qid, r.start_t)):
            h.update(np.asarray([r.qid], dtype=np.int64).tobytes())
            h.update(np.asarray([r.start_t, r.end_t],
                                dtype=np.float64).tobytes())
            h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
            h.update(np.asarray(r.dists, dtype=np.float64).tobytes())
        return json.dumps(rep.summary(), sort_keys=True) + h.hexdigest()

    assert once() == once()


@pytest.mark.parametrize("kind", ["cluster", "graph"])
@pytest.mark.parametrize("delta_kb,flush_frac,par", [
    (2, 0.25, 1),          # tiny delta, eager flushes
    (16, 0.5, 2),          # mid delta, parallel compaction
    (256, 1.0, 1),         # huge delta, lazy flush (mostly unsealed)
])
def test_property_no_tombstone_resurrection_any_schedule(kind, delta_kb,
                                                         flush_frac, par):
    """A deleted id never reappears in merged top-k across any
    compaction schedule — mid-run, at drain, and after a second
    compaction round."""
    data, queries = make_dataset(scaled(DEEP_ANALOG, 360, 10))
    index = _mini_index(kind, data)
    p = _mini_params(kind)
    protected = frozenset([index.meta.medoid]) if kind == "graph" \
        else None
    stream = synth_updates(data, rate_qps=600.0, n_updates=60,
                           delete_frac=0.4, seed=9, protected=protected)
    cfg = IngestConfig(delta_cap_bytes=int(delta_kb) * 1024,
                       flush_frac=flush_frac,
                       compaction_parallelism=par)
    rep = run_workload(index, queries, p, _quiet(TOS), concurrency=4,
                       seed=0, updates=stream, ingest=cfg)
    t_end = max(op.t for op in stream.ops)
    # replay the delete/insert timeline: a query finishing at t must not
    # contain any id whose latest update before t was a delete
    events = sorted(((op.t, op.kind, op.id) for op in stream.ops))
    for r in rep.records:
        if r.end_t <= t_end:
            continue
        dead = set()
        for t, kind_, id_ in events:
            if t > r.start_t:
                break
            (dead.add if kind_ == "delete" else dead.discard)(id_)
        assert not set(int(i) for i in r.ids) & dead
    # post-drain: full compaction keeps every surviving delete dead
    _drain(index)
    final_dead = set()
    for _, kind_, id_ in events:
        (final_dead.add if kind_ == "delete" else final_dead.discard)(id_)
    for q in queries:
        res = index.search(q, p)
        assert not set(int(i) for i in res.ids) & final_dead


# --------------------------------------------------------- tuning axis ---

def test_ingest_screen_write_amplification_shrinks_with_delta():
    from repro.tuning import (EnvSpec, IngestPoint, WorkloadSpec,
                              analytic_write_amplification,
                              resolve_storage, screen_ingest, tune_ingest)
    from repro.tuning.space import Candidate
    w = WorkloadSpec(n=1_000_000, dim=96, write_rate_qps=200.0)
    env = EnvSpec(storage=resolve_storage("tos"))
    c = Candidate(kind="cluster")
    wa_small = analytic_write_amplification(w, c, IngestPoint(64 * 1024))
    wa_big = analytic_write_amplification(w, c,
                                          IngestPoint(4 * 1024 * 1024))
    assert wa_big < wa_small             # bigger deltas amortise
    preds = screen_ingest(w, env, c)
    assert any(p.feasible for p in preds)
    assert preds[0].pred_qps >= preds[-1].pred_qps or \
        not preds[-1].feasible
    with pytest.raises(ValueError):
        tune_ingest(WorkloadSpec(write_rate_qps=0.0), env)


def test_tune_ingest_screen_recommends_fresh_feasible_point():
    from repro.tuning import (EnvSpec, WorkloadSpec, resolve_storage,
                              tune_ingest)
    w = WorkloadSpec(n=500_000, dim=96, concurrency=8,
                     write_rate_qps=100.0)
    env = EnvSpec(storage=resolve_storage("tos"))
    rec = tune_ingest(w, env)
    assert rec.point.delta_cap_bytes > 0
    feas = [p for p in rec.screened if p.feasible]
    best = max(p.pred_qps for p in feas)
    mine = [p for p in feas if p.point == rec.point][0]
    assert mine.pred_qps >= 0.95 * best  # within the slack
