import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import np_sq_l2
from repro.core.kmeans import (BKTree, hierarchical_partition, kmeans_batched,
                               kmeans_np)


def _inertia(x, c, a):
    return float(((x - c[a]) ** 2).sum())


def test_kmeans_np_reduces_inertia():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 16)).astype(np.float32)
    c1, a1 = kmeans_np(x, 8, iters=1, rng=np.random.default_rng(1))
    c8, a8 = kmeans_np(x, 8, iters=8, rng=np.random.default_rng(1))
    assert _inertia(x, c8, a8) <= _inertia(x, c1, a1) * 1.001


def test_kmeans_np_no_empty_clusters():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(300, 8)).astype(np.float32)
    _, a = kmeans_np(x, 16, iters=6)
    assert len(np.unique(a)) == 16


def test_kmeans_balance_enforces_capacity():
    rng = np.random.default_rng(0)
    # heavily skewed data: one dense blob + sparse halo
    x = np.concatenate([
        rng.normal(0, 0.05, size=(800, 8)),
        rng.normal(0, 3.0, size=(200, 8)),
    ]).astype(np.float32)
    _, a0 = kmeans_np(x, 8, iters=10, balance_penalty=0.0,
                      rng=np.random.default_rng(1))
    _, a1 = kmeans_np(x, 8, iters=10, balance_penalty=2.0,
                      rng=np.random.default_rng(1))
    c0 = np.bincount(a0, minlength=8)
    c1 = np.bincount(a1, minlength=8)
    cap = int(np.ceil(1000 / 8 * 1.5))
    assert c1.max() <= cap          # hard capacity honoured
    assert c1.max() < c0.max()      # blob actually split up


def test_kmeans_batched_shapes_and_assign():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, 200, 4))
    c, a = kmeans_batched(key, x, 16, iters=5)
    assert c.shape == (3, 16, 4)
    assert a.shape == (3, 200)
    assert int(a.max()) < 16


def test_hierarchical_partition_covers_all_points():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1000, 12)).astype(np.float32)
    tree, assign = hierarchical_partition(x, n_leaves=64, seed=0)
    assert assign.min() >= 0
    assert len(tree.centroids) >= 16
    # every leaf referenced by assignment exists
    assert assign.max() < len(tree.centroids)
    # leaf centers approximate their members
    for leaf in range(0, len(tree.centroids), 7):
        members = x[assign == leaf]
        if len(members):
            np.testing.assert_allclose(
                tree.centroids[leaf], members.mean(0), atol=1e-3)


def test_bkt_search_agrees_with_flat():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 16)).astype(np.float32)
    tree, _ = hierarchical_partition(x, n_leaves=128, seed=0)
    q = rng.normal(size=(16,)).astype(np.float32)
    flat = tree.flat_search(q, 10)
    bkt, ndist = tree.search(q, 10, overquery=8)
    # best-first descent with generous overquery should recover most of the
    # exact top set, at sublinear distance-comp cost
    overlap = len(np.intersect1d(flat, bkt)) / 10
    assert overlap >= 0.6
    assert ndist < len(tree.centroids) * 1.5
    # nearest leaf must always be found
    assert flat[0] in bkt
