"""repro.obs.monitor + repro.obs.cost: live SLO monitors and dollar
metering observe without perturbing (golden bit-exactness), burn-rate
alerts fire and actuate only when asked, and per-tenant show-back sums
to the fleet total exactly.  Plus the histogram-quantile error-bound
property test and MetricsRegistry edge cases (PR 7 satellites)."""
import dataclasses
import hashlib
import json
import math
import os

import numpy as np
import pytest

from repro.core.cluster_index import ClusterIndex
from repro.core.types import ClusterIndexParams, SearchParams
from repro.data.synth import DEEP_ANALOG, make_dataset, scaled
from repro.fleet import FleetConfig, run_fleet
from repro.obs import (PRICEBOOKS, ActionBus, AlertLog, BurnRateRule,
                       MetricsRegistry, MonitorConfig, PriceBook,
                       SLOMonitor, Tracer, chrome_trace, fleet_cost,
                       flame_summary, format_showback, resolve_pricebook,
                       tenant_showback)
from repro.sim.arrivals import Poisson
from repro.tenancy import run_tenant_fleet
from repro.tenancy.spec import TenantSpec

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_fleet_prerefactor.json")

HEDGED_CFG = FleetConfig(n_shards=4, replication=2, concurrency=16,
                         shard_concurrency=4, queue_depth=16,
                         hedge=True, hedge_percentile=75.0, seed=5)


@pytest.fixture(scope="module")
def setup():
    spec = scaled(DEEP_ANALOG, 1200, 32)
    data, queries = make_dataset(spec)
    ci = ClusterIndex.build(data, ClusterIndexParams(kmeans_iters=4, seed=0))
    return data, queries, ci


def _ids_sha256(report) -> str:
    h = hashlib.sha256()
    for r in sorted(report.records, key=lambda r: r.qid):
        h.update(np.asarray(r.qid).tobytes())
        h.update(np.asarray(r.ids, dtype=np.int64).tobytes())
    return h.hexdigest()


# ----------------------------------------------------- bit-exactness --

def test_monitored_priced_run_reproduces_golden(setup):
    """Acceptance: monitoring + costing (without alert actions) still
    reproduces the pre-refactor golden reports bit for bit — the
    monitor ticker only consumes kernel sequence numbers and pricing is
    post-hoc arithmetic."""
    _, queries, ci = setup
    golden = json.load(open(GOLDEN_PATH))
    p = SearchParams(k=golden["params"]["k"],
                     nprobe=golden["params"]["nprobe"])
    configs = dict(
        one_shard=FleetConfig(n_shards=1, replication=1, concurrency=8,
                              shard_concurrency=8, queue_depth=64, seed=0),
        four_shard=HEDGED_CFG)
    for name, cfg in configs.items():
        rep = run_fleet(ci, queries, p, cfg, monitor=MonitorConfig(),
                        pricebook=PRICEBOOKS["default"])
        g = golden[name]
        assert rep.wall_time_s == pytest.approx(g["wall_time_s"],
                                                rel=1e-9, abs=1e-12)
        assert rep.qps == pytest.approx(g["qps"], rel=1e-9)
        assert _ids_sha256(rep) == g["ids_sha256"]


def test_monitored_summary_equals_plain_minus_obs_blocks(setup):
    """The report of a monitored + priced run is the plain report plus
    exactly two new keys (``alerts``, ``cost``) — nothing else moves."""
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    plain = run_fleet(ci, queries, p, HEDGED_CFG)
    mon = run_fleet(ci, queries, p, HEDGED_CFG, monitor=MonitorConfig(),
                    pricebook=PRICEBOOKS["default"])
    s_plain, s_mon = plain.summary(), mon.summary()
    assert "alerts" not in s_plain and "cost" not in s_plain
    assert s_mon.pop("alerts") is not None
    assert s_mon.pop("cost") is not None
    assert s_mon == s_plain


def test_monitored_traced_open_loop_bit_exact(setup):
    """Monitor + pricebook + tracer stacked on an open-loop run with an
    SLO (the monitor actually observing misses) stays bit-exact."""
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=7)
    mk = lambda: Poisson(rate_qps=600.0, n_total=2 * len(queries))
    plain = run_fleet(ci, queries, p, cfg, arrivals=mk(), slo_s=0.02)
    mon = run_fleet(ci, queries, p, cfg, arrivals=mk(), slo_s=0.02,
                    tracer=Tracer(), monitor=MonitorConfig(),
                    pricebook=PRICEBOOKS["default"])
    s = mon.summary()
    s.pop("alerts"), s.pop("cost")
    assert s == plain.summary()


# --------------------------------------------------- alerts + actions --

@pytest.fixture(scope="module")
def overload(setup):
    """One sustained-overload run observed, one with actions enabled."""
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=3)
    mk = lambda: Poisson(rate_qps=3000.0, n_total=8 * len(queries))
    observed = run_fleet(ci, queries, p, cfg, arrivals=mk(), slo_s=0.005,
                         monitor=MonitorConfig(),
                         pricebook=PRICEBOOKS["default"])
    acted = run_fleet(ci, queries, p, cfg, arrivals=mk(), slo_s=0.005,
                      monitor=MonitorConfig(actions=True),
                      pricebook=PRICEBOOKS["default"])
    return observed, acted


def test_sustained_overload_fires_burn_alerts(overload):
    observed, _ = overload
    fired = observed.alerts["fired"]
    assert fired, "sustained SLO miss must fire at least one alert"
    by_rule = {a["rule"] for a in fired}
    assert "fast" in by_rule            # page on the hard burn
    for a in fired:
        assert a["monitor"] == "fleet.latency"
        assert a["peak_burn"] > 0
    # observation only: no actions were taken
    assert observed.alerts["actions"] == []


def test_alert_actions_scale_out_under_overload(overload):
    """Acceptance: with actions on, a sustained p99 burn produces at
    least one alert-driven scale-out in the fleet report."""
    _, acted = overload
    actions = acted.alerts["actions"]
    assert any(a["action"] == "scale_up" for a in actions)
    up = next(a for a in actions if a["action"] == "scale_up")
    assert up["monitor"] == "fleet.latency"
    assert up["instances"] > 2          # 2 shards x 1 replica at start


def test_alert_actions_deprioritize_over_budget_tenant():
    """The admission-layer subscriber: a tenant sustaining a ticket-
    severity latency burn gets its admission window shrunk."""
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=4, seed=3)
    hog = TenantSpec(name="hog", n=500, dim=32, n_queries=32, nprobe=16,
                     scenario="poisson", rate_qps=2500.0, n_arrivals=600,
                     slo_ms=4.0)
    quiet = TenantSpec(name="quiet", n=500, dim=32, n_queries=16,
                       nprobe=4, scenario="poisson", rate_qps=50.0,
                       n_arrivals=60, slo_ms=200.0)
    rep = run_tenant_fleet([hog, quiet], cfg, "shared",
                           monitor=MonitorConfig(actions=True))
    actions = rep.fleet.alerts["actions"]
    dep = [a for a in actions if a["action"] == "deprioritize"]
    assert dep and dep[0]["tenant"] == "hog"
    assert dep[0]["window"] >= 1


def test_autoscaler_alert_hook_respects_cooldown():
    from repro.sim.autoscale import AutoscaleConfig, Autoscaler
    from repro.obs.monitor import Alert

    class StubFleet:
        total_instances = 2
        recent_sojourns = ()

        def scale_up_one(self):
            self.total_instances += 1
            return True

        def scale_down_one(self):
            return False

    a = Autoscaler(AutoscaleConfig(slo_p99_s=0.05, cooldown_s=0.25),
                   StubFleet())
    alert = Alert(monitor="fleet.latency", rule="fast", severity="page",
                  fired_t=1.0)
    assert a.alert_scale_up(1.0, alert) is True
    assert a.alert_scale_up(1.1, alert) is False     # inside cooldown
    assert a.alert_scale_up(1.3, alert) is True      # cooldown elapsed
    assert a.events[0]["reason"] == "alert:fleet.latency/fast"


def test_admission_window_shrink_drains_in_flight():
    """Mid-run window shrink (the deprioritize action): in-flight items
    above the new window drain off before the backlog moves again."""
    from repro.sim.admission import AdmissionWindow
    from repro.sim.kernel import Kernel

    started = []
    win = AdmissionWindow(Kernel(seed=0), 2, lambda it, t: started.append(it))
    for i in range(4):
        win.offer(i)
    assert started == [0, 1] and win.in_window == 2
    win.window = 1                       # the deprioritize action
    assert win.release(0.1) is False     # drains: 2 in flight > window 1
    assert win.in_window == 1 and started == [0, 1]
    assert win.release(0.2) is True      # now the backlog moves again
    assert started == [0, 1, 2] and win.in_window == 1


# --------------------------------------------- monitor unit behaviour --

def test_burn_rate_math_and_min_samples():
    m = SLOMonitor("x", objective=0.99, min_samples=8)
    for i in range(6):
        m.observe(i * 0.01, bad=True)
    assert m.burn_rate(0.06, 0.25) == 0.0        # below min_samples
    for i in range(6, 10):
        m.observe(i * 0.01, bad=(i % 2 == 0))
    n, bad = m.window_counts(0.09, 0.25)
    assert (n, bad) == (10, 8)
    assert m.burn_rate(0.09, 0.25) == pytest.approx((8 / 10) / 0.01)


def test_alert_log_fire_update_clear_cycle():
    log = AlertLog()
    m = SLOMonitor("fleet.latency", objective=0.99)
    rule = BurnRateRule("fast", long_s=0.25, short_s=0.05, threshold=8.0)
    a = log.fire(0.1, m, rule, burn=12.0)
    assert a is not None and a.active and a.peak_burn == 12.0
    assert log.fire(0.2, m, rule, burn=20.0) is None   # already firing
    assert a.peak_burn == 20.0                         # peak updated
    cleared = log.clear(0.3, m, rule)
    assert cleared is a and a.cleared_t == 0.3 and not a.active
    assert log.clear(0.4, m, rule) is None
    assert [d["peak_burn"] for d in log.to_dicts()] == [20.0]


def test_action_bus_disabled_never_calls_subscribers():
    calls = []
    bus = ActionBus(enabled=False)
    bus.subscribe(lambda ev, al, now: calls.append(ev))
    bus.publish("fired", None, 0.0)
    assert calls == []
    bus.enabled = True
    bus.publish("fired", None, 0.0)
    assert calls == ["fired"]


def test_rule_and_config_validation():
    with pytest.raises(ValueError):
        BurnRateRule("bad", long_s=0.05, short_s=0.25, threshold=8.0)
    with pytest.raises(ValueError):
        BurnRateRule("bad", long_s=0.25, short_s=0.05, threshold=0.0)
    with pytest.raises(ValueError):
        MonitorConfig(interval_s=0.0)
    with pytest.raises(ValueError):
        MonitorConfig(rules=())
    with pytest.raises(ValueError):
        SLOMonitor("x", objective=1.0)
    # gt_ids is carried data, not config
    assert "gt_ids" not in MonitorConfig(gt_ids=np.zeros((4, 10))).to_dict()


# -------------------------------------------------------------- cost --

def test_pricebook_validation_and_resolution(tmp_path):
    with pytest.raises(ValueError):
        PriceBook(get_per_million_usd=-0.1)
    with pytest.raises(ValueError):
        PriceBook.from_dict(dict(gets_per_million=1.0))
    assert resolve_pricebook("egress-heavy").egress_per_gib_usd == 0.09
    custom = tmp_path / "book.json"
    custom.write_text(json.dumps(dict(get_per_million_usd=1.0)))
    book = resolve_pricebook(str(custom))
    assert book.name == "book.json"
    assert book.get_per_million_usd == 1.0
    with pytest.raises(KeyError):
        resolve_pricebook("no-such-book")


def test_fleet_cost_components_and_unit_economics(setup):
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    rep = run_fleet(ci, queries, p, HEDGED_CFG)
    book = PRICEBOOKS["default"]
    cost = fleet_cost(rep, HEDGED_CFG, book)
    comp_sum = sum(cost[k] for k in ("get_usd", "put_usd", "egress_usd",
                                     "instance_usd", "cache_usd"))
    assert cost["total_usd"] == pytest.approx(comp_sum, abs=5e-9)
    assert cost["get_usd"] > 0 and cost["egress_usd"] > 0
    assert cost["put_usd"] == 0.0          # pure-query run: no PUTs
    assert cost["usd_per_1k_queries"] == pytest.approx(
        cost["total_usd"] / len(rep.records) * 1000.0, rel=1e-5)
    assert cost["queries_per_usd"] > 0
    # doubling every price doubles the bill
    double = PriceBook.from_dict({
        f.name: (getattr(book, f.name) * 2
                 if f.name != "name" else "double")
        for f in dataclasses.fields(PriceBook)})
    assert fleet_cost(rep, HEDGED_CFG, double)["total_usd"] == \
        pytest.approx(2 * cost["total_usd"], abs=5e-9)


def test_rw_run_meters_compaction_puts(setup):
    """PUT metering: compaction writes show up as PUT requests (priced
    ~12x a GET) and are a subset of the storage totals."""
    from repro.ingest.compaction import IngestConfig
    from repro.ingest.stream import synth_updates
    data, queries, ci = setup
    stream = synth_updates(data, rate_qps=600.0, n_updates=120,
                           delete_frac=0.2, seed=3)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=3)
    rep = run_fleet(ci, queries, SearchParams(k=10, nprobe=16), cfg,
                    updates=stream,
                    ingest=IngestConfig(delta_cap_bytes=4 * 1024),
                    pricebook=PRICEBOOKS["default"])
    puts = sum(s.storage_put_requests for s in rep.shard_stats)
    put_bytes = sum(s.storage_put_bytes for s in rep.shard_stats)
    assert puts > 0 and put_bytes > 0
    assert puts <= rep.storage_requests
    assert put_bytes <= rep.storage_bytes
    assert rep.cost["put_usd"] > 0


def test_showback_rows_sum_to_fleet_total():
    """Acceptance: per-tenant dollars + the (unattributed) row sum to
    the fleet total within float error, with shared costs apportioned
    by executed-job share."""
    cfg = FleetConfig(n_shards=2, replication=2, concurrency=6,
                      cache_bytes=64 * 1024, cache_policy="slru", seed=3)
    a = TenantSpec(name="a", n=500, dim=32, n_queries=24, nprobe=8)
    b = TenantSpec(name="b", n=400, dim=32, n_queries=16, nprobe=8)
    rep = run_tenant_fleet([a, b], cfg, "weighted",
                           pricebook=PRICEBOOKS["default"])
    sb = rep.showback
    assert math.isclose(sb["sum_usd"], sb["fleet_total_usd"],
                        rel_tol=1e-9, abs_tol=1e-12)
    assert [r["tenant"] for r in sb["rows"]] == ["a", "b",
                                                "(unattributed)"]
    shares = [r["shared_share"] for r in sb["rows"]]
    assert sum(shares) == pytest.approx(1.0, abs=1e-5)
    for r in sb["rows"]:
        assert r["total_usd"] == pytest.approx(
            r["get_usd"] + r["put_usd"] + r["egress_usd"]
            + r["shared_usd"], abs=5e-9)
    # each tenant's cost row also rides on its slice
    assert rep.tenants[0].cost["tenant"] == "a"
    table = format_showback(sb)
    assert "(unattributed)" in table and "pricebook=default" in table


def test_tenancy_monitored_summary_bit_exact():
    cfg = FleetConfig(n_shards=2, replication=2, concurrency=6,
                      cache_bytes=64 * 1024, cache_policy="slru", seed=3)
    mk = lambda: [TenantSpec(name="a", n=500, dim=32, n_queries=24,
                             nprobe=8),
                  TenantSpec(name="b", n=400, dim=32, n_queries=16,
                             nprobe=8)]
    plain = run_tenant_fleet(mk(), cfg, "weighted").summary()
    mon = run_tenant_fleet(mk(), cfg, "weighted",
                           monitor=MonitorConfig(),
                           pricebook=PRICEBOOKS["default"]).summary()
    assert mon.pop("showback") is not None
    assert mon["fleet"].pop("alerts") is not None
    assert mon["fleet"].pop("cost") is not None
    for t in mon["tenants"]:
        assert t.pop("cost") is not None
    plain.pop("showback", None)
    for t in plain["tenants"]:
        t.pop("cost", None)
    assert mon == plain


def test_showback_synthetic_exact_sum():
    """Unit-level: hand-built slices with known counts sum exactly and
    the residual row carries exactly the unattributed I/O."""

    class Metrics:
        def __init__(self, lookups, hits, nbytes):
            self.cache_lookups = lookups
            self.cache_hits = hits
            self.bytes_storage = nbytes

    class Rec:
        def __init__(self, lookups, hits, nbytes, n_jobs):
            self.metrics = Metrics(lookups, hits, nbytes)
            self.n_jobs = n_jobs

    class Slice:
        def __init__(self, name, records, ingest=None):
            self.name = name
            self.records = records
            self.ingest = ingest

    class Stats:
        storage_put_requests = 10
        storage_put_bytes = 1000

    class Report:
        shard_stats = [Stats()]
        storage_requests = 100 + 10     # 90 attributable + 10 stray GETs
        storage_bytes = 20000 + 1000
        shards_seconds = 7.2
        records = []
        good_total = None

    cfg = FleetConfig(n_shards=1, replication=1, cache_bytes=2**30)
    book = PriceBook()
    tenants = [
        Slice("a", [Rec(40, 10, 8000, 3)],
              ingest=dict(compaction_read_requests=20,
                          compaction_read_bytes=4000,
                          compaction_write_requests=10)),
        Slice("b", [Rec(50, 10, 6000, 1)]),
    ]
    sb = tenant_showback(tenants, Report(), cfg, book)
    assert math.isclose(sb["sum_usd"], sb["fleet_total_usd"],
                        rel_tol=1e-12, abs_tol=1e-15)
    un = sb["rows"][-1]
    # stray = 100 total GETs - (30+20) - 40 attributed
    assert un["get_usd"] == pytest.approx(10 / 1e6 * 0.40)
    assert un["put_usd"] == 0.0
    assert sb["rows"][0]["shared_share"] == 0.75   # 3 of 4 jobs


# ---------------------------------- histogram exactness (satellite 1) --

def test_histogram_quantile_exactness():
    """Property sweep: for in-range samples the estimate is within the
    documented per-bucket relative-error bound of the true inverted-CDF
    sample quantile — ratio within [1/base, base], base =
    10**(1/buckets_per_decade)."""
    from repro.obs.metrics import Histogram
    rng = np.random.default_rng(0)
    base = 10.0 ** (1.0 / 8)
    for trial in range(60):
        h = Histogram("x")
        n = int(rng.integers(5, 400))
        kind = trial % 3
        if kind == 0:
            xs = rng.lognormal(mean=-6, sigma=2.0, size=n)
        elif kind == 1:
            xs = rng.exponential(0.01, size=n)
        else:
            xs = rng.uniform(1e-5, 10.0, size=n)
        xs = np.clip(xs, h.lo, h.hi * 0.999)
        for x in xs:
            h.observe(float(x))
        for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.99):
            est = h.quantile(q)
            true = float(np.quantile(xs, q, method="inverted_cdf"))
            ratio = est / true
            assert 1.0 / base - 1e-9 <= ratio <= base + 1e-9, \
                (trial, q, est, true)
        # the clamp makes the extremes exact
        assert h.quantile(0.0) == pytest.approx(float(xs.min()))
        assert h.quantile(1.0) == pytest.approx(float(xs.max()))


def test_histogram_out_of_range_stays_inside_observed():
    """Samples outside [lo, hi) clamp into the edge buckets, whose
    edges no longer bracket them — but every quantile estimate still
    stays inside the observed [min, max]."""
    from repro.obs.metrics import Histogram
    h = Histogram("x", lo=1e-3, hi=1.0)
    h.observe(1e-6)          # below lo: first bucket
    h.observe(50.0)          # above hi: last bucket
    for q in (0.0, 0.25, 0.5, 0.75, 1.0):
        assert 1e-6 <= h.quantile(q) <= 50.0


# ------------------------------- registry edge cases (satellite 2) --

def test_empty_histogram_quantile_is_zero():
    from repro.obs.metrics import Histogram
    h = Histogram("x")
    assert h.quantile(0.5) == 0.0
    d = h.to_dict()
    assert d["count"] == 0 and d["min"] == 0.0 and d["max"] == 0.0


def test_gauge_snapshot_after_set_ordering():
    """A snapshot sees the latest set() before it, never one after."""
    m = MetricsRegistry()
    m.gauge("depth").set(3)
    m.snapshot(0.1)
    m.gauge("depth").set(9)
    m.snapshot(0.2)
    m.gauge("depth").set(1)          # after the last snapshot: unseen
    assert [row["depth"] for _, row in m.series] == [3.0, 9.0]


def test_counter_first_published_mid_run():
    """A counter that first appears between snapshots shows up in rows
    from that point on — earlier rows do not retroactively gain it."""
    m = MetricsRegistry()
    m.counter("q").inc()
    m.snapshot(0.1)
    m.counter("late").inc(5)         # first published mid-run
    m.snapshot(0.2)
    (t0, row0), (t1, row1) = m.series
    assert "late" not in row0
    assert row1["late"] == 5.0
    # and the export's counter tracks stay deterministic across calls
    tr = Tracer()
    tr.metrics = m
    a = [e for e in chrome_trace(tr)["traceEvents"] if e["ph"] == "C"]
    b = [e for e in chrome_trace(tr)["traceEvents"] if e["ph"] == "C"]
    assert a == b
    assert [e["name"] for e in a] == ["q", "late", "q"]


# -------------------------------------- trace export (satellite 3) --

@pytest.fixture(scope="module")
def traced_overload(setup):
    _, queries, ci = setup
    p = SearchParams(k=10, nprobe=16)
    cfg = FleetConfig(n_shards=2, replication=1, concurrency=8, seed=3)
    tracer = Tracer()
    rep = run_fleet(ci, queries, p, cfg,
                    arrivals=Poisson(rate_qps=3000.0,
                                     n_total=8 * len(queries)),
                    slo_s=0.005, tracer=tracer,
                    monitor=MonitorConfig(actions=True),
                    pricebook=PRICEBOOKS["default"])
    return rep, tracer


def test_export_alert_instants_and_cost_tracks(traced_overload):
    rep, tracer = traced_overload
    doc = chrome_trace(tracer)
    events = doc["traceEvents"]
    alert_ev = [e for e in events if e.get("cat") == "alert"]
    assert {e["name"] for e in alert_ev} >= {"alert_fired"}
    assert any(e["name"].startswith("alert_action_") for e in alert_ev)
    for e in alert_ev:
        assert e["ph"] == "i"
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert {"cost.total_usd", "cost.get_usd",
            "slo.fleet.latency.burn", "slo.fleet.latency.p99_s"} <= counters
    # cost counter track is monotone non-decreasing in time
    track = [(e["ts"], e["args"]["value"]) for e in events
             if e["ph"] == "C" and e["name"] == "cost.total_usd"]
    assert track == sorted(track)
    vals = [v for _, v in track]
    assert vals == sorted(vals) and vals[-1] > 0


def test_export_deterministic_with_monitor(traced_overload):
    _, tracer = traced_overload
    assert chrome_trace(tracer) == chrome_trace(tracer)
    assert flame_summary(tracer) == flame_summary(tracer)
    assert "query" in flame_summary(tracer)


# ---------------------------------------------------------------- CLI --

def test_fleet_cli_monitor_and_pricebook(capsys):
    from repro.fleet.__main__ import main
    rc = main(["--shards", "2", "--n", "600", "--queries", "16",
               "--monitor", "--pricebook", "default", "--compact"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["report"]["cost"]["pricebook"] == "default"
    assert "monitors" in out["report"]["alerts"]


def test_fleet_cli_flags_unset_emit_no_obs_blocks(capsys):
    from repro.fleet.__main__ import main
    rc = main(["--shards", "2", "--n", "600", "--queries", "16",
               "--compact"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert "cost" not in out["report"] and "alerts" not in out["report"]


def test_cli_alert_actions_requires_monitor():
    from repro.fleet.__main__ import main
    with pytest.raises(SystemExit):
        main(["--shards", "2", "--n", "600", "--queries", "16",
              "--alert-actions", "--compact"])


def test_cli_unknown_pricebook_errors():
    from repro.fleet.__main__ import main
    with pytest.raises(SystemExit):
        main(["--shards", "2", "--n", "600", "--queries", "16",
              "--pricebook", "no-such-book", "--compact"])
