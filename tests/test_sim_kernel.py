"""The discrete-event kernel: ordering, determinism, timers, RNG streams."""
import numpy as np
import pytest

from repro.sim import Kernel
from repro.sim.arrivals import (ClosedLoop, Poisson, Scenario, Trace, burst,
                                diurnal, zipf_trace)


# ------------------------------------------------------------- ordering --

def test_events_fire_in_time_order():
    k = Kernel()
    fired = []
    for t in (0.3, 0.1, 0.2):
        k.at(t, fired.append, t)
    k.run()
    assert fired == [0.1, 0.2, 0.3]
    assert k.now == 0.3


def test_same_time_events_fire_in_insertion_order():
    """The seq tie-break: same-instant events keep program order."""
    k = Kernel()
    fired = []
    for i in range(50):
        k.at(1.0, fired.append, i)
    k.at(0.5, fired.append, "early")
    k.run()
    assert fired == ["early"] + list(range(50))


def test_tie_heavy_schedule_is_deterministic():
    """A schedule with many ties replays identically: the (time, seq)
    total order leaves nothing to dict/hash/heap ambiguity."""
    def run_once():
        k = Kernel(seed=7)
        rng = k.rng("gen")
        fired = []
        times = rng.choice([0.0, 0.1, 0.2, 0.3], size=200)
        for i, t in enumerate(times):
            # half the events schedule a same-time follow-up: cascades at
            # equal timestamps are the hard case for determinism
            if i % 2:
                k.at(float(t), lambda i=i, t=t: (
                    fired.append(("a", i)),
                    k.at(float(t), fired.append, ("b", i))))
            else:
                k.at(float(t), fired.append, ("c", i))
        k.run()
        return fired

    assert run_once() == run_once()


def test_cancelled_events_do_not_fire():
    k = Kernel()
    fired = []
    ev = k.at(1.0, fired.append, "cancelled")
    k.at(2.0, fired.append, "kept")
    k.cancel(ev)
    k.run()
    assert fired == ["kept"]
    assert len(k.queue) == 0


def test_cannot_schedule_in_the_past():
    k = Kernel()
    k.at(1.0, lambda: None)
    k.run()
    with pytest.raises(ValueError, match="before now"):
        k.at(0.5, lambda: None)


def test_run_until_is_inclusive_and_advances_clock():
    k = Kernel()
    fired = []
    for t in (0.5, 1.0, 1.5):
        k.at(t, fired.append, t)
    k.run_until(1.0)
    assert fired == [0.5, 1.0]
    assert k.now == 1.0
    k.run()
    assert fired == [0.5, 1.0, 1.5]


def test_run_max_events_guard_raises():
    k = Kernel()

    def loop():
        k.after(0.001, loop)

    loop()
    with pytest.raises(RuntimeError, match="without draining"):
        k.run(max_events=1000)


def test_ticker_repeats_until_cancelled():
    k = Kernel()
    ticks = []
    ticker = k.every(0.1, ticks.append)
    k.at(0.55, ticker.cancel)
    k.run()
    assert ticks == pytest.approx([0.1, 0.2, 0.3, 0.4, 0.5])


def test_ticker_cancel_before_first_fire():
    k = Kernel()
    ticks = []
    ticker = k.every(0.1, ticks.append)
    ticker.cancel()
    k.at(0.5, lambda: None)      # keep the kernel non-empty past t=0.1
    k.run()
    assert ticks == []
    assert ticker.cancelled


def test_ticker_double_cancel_is_idempotent():
    k = Kernel()
    ticker = k.every(0.1, lambda now: None)
    k.at(0.15, ticker.cancel)
    k.at(0.25, ticker.cancel)    # second cancel must be a no-op
    k.run()
    assert ticker.cancelled


def test_ticker_cancel_from_within_fn():
    k = Kernel()
    ticks = []

    def fn(now):
        ticks.append(now)
        if len(ticks) == 3:
            ticker.cancel()

    ticker = k.every(0.1, fn)
    k.run()
    assert ticks == pytest.approx([0.1, 0.2, 0.3])


def test_event_repr_includes_span_context():
    """With a tracer attached, an event scheduled under a span names it;
    without one, repr is unchanged."""
    from repro.obs import Tracer
    k = Kernel()
    ev_plain = k.at(1.0, lambda: None)
    assert "span=" not in repr(ev_plain)
    tr = Tracer()
    tr.attach(k)
    sp = tr.begin("query", 0.0, qid=1)
    k.current_span = sp
    ev = k.at(1.0, lambda: None)
    assert f"span=query#{sp.sid}" in repr(ev)


# ----------------------------------------------------------- rng streams --

def test_named_rng_streams_are_independent():
    """Drawing from one stream never shifts another's sequence."""
    k1 = Kernel(seed=3)
    a_only = k1.rng("a").random(5)

    k2 = Kernel(seed=3)
    k2.rng("b").random(100)          # interleaved consumer
    a_with_b = k2.rng("a").random(5)
    np.testing.assert_array_equal(a_only, a_with_b)

    # different names, different streams; different seeds too
    assert not np.allclose(a_only, Kernel(seed=3).rng("c").random(5))
    assert not np.allclose(a_only, Kernel(seed=4).rng("a").random(5))


def test_explicit_seed_pins_stream():
    got = Kernel(seed=99).rng("storage", seed=42).normal(size=4)
    np.testing.assert_array_equal(got,
                                  np.random.default_rng(42).normal(size=4))


def test_unique_name_is_deterministic():
    k = Kernel()
    assert [k.unique_name("storage") for _ in range(3)] == \
        ["storage#0", "storage#1", "storage#2"]


# -------------------------------------------------------------- arrivals --

def test_closed_loop_arrives_everything_at_t0():
    k = Kernel()
    seen = []
    ClosedLoop(4, n_total=6).start(k, lambda i, wi: seen.append((i, wi)), 3)
    assert seen == [(0, 0), (1, 1), (2, 2), (3, 0), (4, 1), (5, 2)]


def test_poisson_rate_and_determinism():
    def arrivals(seed):
        k = Kernel(seed=seed)
        times = []
        Poisson(1000.0, duration_s=2.0).start(
            k, lambda i, wi: times.append(k.now), 10)
        k.run()
        return times

    a, b = arrivals(1), arrivals(1)
    assert a == b                          # same seed, same arrivals
    assert arrivals(2) != a                # seed moves the sample path
    rate = len(a) / a[-1]
    assert rate == pytest.approx(1000.0, rel=0.1)
    assert all(t <= 2.0 for t in a)


def test_burst_modulation_concentrates_arrivals():
    k = Kernel(seed=0)
    times = []
    Poisson(500.0, duration_s=1.0,
            modulation=burst(0.4, 0.6, 8.0)).start(
        k, lambda i, wi: times.append(k.now), 10)
    k.run()
    t = np.asarray(times)
    in_burst = ((t >= 0.4) & (t < 0.6)).sum()
    # the 0.2s burst window at 8x carries ~62% of all arrivals
    assert in_burst / len(t) > 0.4


def test_diurnal_modulation_validates_and_oscillates():
    with pytest.raises(ValueError):
        diurnal(1.0, amplitude=1.5)
    m = diurnal(1.0, amplitude=0.5)
    assert m(0.25) == pytest.approx(1.5)
    assert m(0.75) == pytest.approx(0.5)


def test_trace_replays_exact_times_and_qids():
    k = Kernel()
    seen = []
    Trace([0.1, 0.2, 0.2, 0.5], qids=[3, 1, 4, 1]).start(
        k, lambda i, wi: seen.append((round(k.now, 6), i, wi)), 10)
    k.run()
    assert seen == [(0.1, 0, 3), (0.2, 1, 1), (0.2, 2, 4), (0.5, 3, 1)]


def test_trace_validation():
    with pytest.raises(ValueError):
        Trace([])
    with pytest.raises(ValueError):
        Trace([0.2, 0.1])
    with pytest.raises(ValueError):
        Trace([0.1, 0.2], qids=[1])


def test_zipf_trace_is_long_tailed_and_deterministic():
    tr1 = zipf_trace(64, rate_qps=100.0, n_total=500, seed=5)
    tr2 = zipf_trace(64, rate_qps=100.0, n_total=500, seed=5)
    np.testing.assert_array_equal(tr1.times, tr2.times)
    np.testing.assert_array_equal(tr1.qids, tr2.qids)
    # the hottest query dominates (zipf head)
    _, counts = np.unique(tr1.qids, return_counts=True)
    assert counts.max() > 0.3 * len(tr1.qids)


def test_scenario_factory_and_validation():
    with pytest.raises(ValueError):
        Scenario(kind="chaos")
    with pytest.raises(ValueError):
        Scenario(slo_s=0.0)
    assert isinstance(Scenario(kind="closed").make_arrivals(8, 4),
                      ClosedLoop)
    arr = Scenario(kind="poisson", rate_qps=100.0,
                   duration_s=1.0).make_arrivals(8, 4)
    assert isinstance(arr, Poisson)
    assert Scenario(kind="burst").make_arrivals(8, 4).modulation is not None
    assert isinstance(Scenario(kind="trace", n_arrivals=50
                               ).make_arrivals(8, 4), Trace)


def test_event_order_property_under_tie_heavy_schedules():
    """Property test (hypothesis): for any schedule drawn from a tiny
    time domain (maximally tie-heavy), events fire sorted by time with
    ties in insertion order, and a replay is identical."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None)
    @given(times=st.lists(st.sampled_from([0.0, 0.25, 0.5, 0.75]),
                          min_size=1, max_size=64))
    def prop(times):
        def run_once():
            k = Kernel()
            fired = []
            for i, t in enumerate(times):
                k.at(t, fired.append, (t, i))
            k.run()
            return fired

        fired = run_once()
        assert fired == sorted(fired)          # (time, seq) total order
        assert [i for _, i in fired] == sorted(
            range(len(times)), key=lambda i: (times[i], i))
        assert fired == run_once()             # bit-identical replay

    prop()


def test_arrival_done_callback_fires_after_last_arrival():
    for proc in (ClosedLoop(2, n_total=4),
                 Poisson(200.0, n_total=4),
                 Trace([0.0, 0.1, 0.2, 0.3])):
        k = Kernel(seed=0)
        log = []
        proc.start(k, lambda i, wi: log.append(("arrive", i)), 4,
                   done=lambda: log.append(("done",)))
        k.run()
        assert log[-1] == ("done",)
        assert sum(1 for e in log if e[0] == "arrive") == 4
